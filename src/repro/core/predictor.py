"""§4.7 Online latency prediction.

Keyed by *operator node* — (launch queue, ordinal index within a batch) —
not by kernel function name: one kernel function serves layers with
different tensor sizes, so identity-by-name mispredicts (§4.7).  Sync events
reset the ordinal counter, delimiting batches.

Per node the predictor records observations conditioned on (slices,
frequency, atom fraction) and answers queries for unseen conditions with the
paper's conservative fallback: optimal linear scaling from the nearest
observed condition (e.g. seen at 100% TPCs -> assume half the slices takes
2x as long).
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Optional

from repro.core.types import CompletionRecord, KernelTask

EWMA = 0.3              # new-observation weight


def _fkey(f: float) -> int:
    return round(f * 100)


@dataclass
class NodeStats:
    """Observations for one operator node."""

    # (slices, f%) -> EWMA of *full-kernel-equivalent* latency minus overhead
    lat: dict[tuple[int, int], float] = field(default_factory=dict)
    count: int = 0
    total_runtime: float = 0.0          # for DVFS weights

    def observe(self, slices: int, f: float, unit_latency: float):
        k = (slices, _fkey(f))
        old = self.lat.get(k)
        self.lat[k] = (unit_latency if old is None
                       else (1 - EWMA) * old + EWMA * unit_latency)
        self.count += 1
        self.total_runtime += unit_latency


class LatencyPredictor:
    """Online, per-queue kernel latency predictor."""

    def __init__(self, launch_overhead: float = 4e-6):
        self.nodes: dict[tuple[int, int], NodeStats] = defaultdict(NodeStats)
        self.overhead = launch_overhead
        self.mispredictions = 0
        self.predictions = 0
        self.errors: list[float] = []

    # -- observation --------------------------------------------------------

    def observe(self, rec: CompletionRecord):
        task = rec.task
        frac = 1.0
        if task.atom_of is not None:
            _, _, n_atoms = task.atom_of
            frac = task.work.n_blocks and 1.0  # atoms carry scaled work
        # normalize to full-kernel-equivalent divisible latency
        div = max(rec.latency - self.overhead, 1e-9)
        if task.atom_of is not None:
            _, _, n = task.atom_of
            div *= n          # approx: atoms are ~equal slices of the kernel
        self.nodes[task.key()].observe(rec.slices, rec.freq, div)

    def seed_node(self, queue_id: int, ordinal: int, slices: int, f: float,
                  latency: float):
        """Warm-start one operator node with a synthetic observation (e.g.
        a roofline-calibrated decode latency) so a serving tenant's first
        iterations aren't scheduled under the conservative unseen-kernel
        default.  ``latency`` is a whole-launch latency; the launch
        overhead is stripped exactly as observe() does."""
        self.nodes[(queue_id, ordinal)].observe(
            slices, f, max(latency - self.overhead, 1e-9))

    # -- queries ------------------------------------------------------------

    def known(self, task: KernelTask) -> bool:
        return self.nodes[task.key()].count > 0

    def predict(self, task: KernelTask, slices: int, f: float = 1.0,
                n_atoms: int = 1) -> Optional[float]:
        """Predicted latency of one launch (kernel, or one of n_atoms atoms).

        Returns None for never-seen nodes (callers apply their own
        conservative default).
        """
        node = self.nodes.get(task.key())
        if not node or not node.lat:
            return None
        k = (slices, _fkey(f))
        if k in node.lat:
            div = node.lat[k]
        else:
            # conservative fallback: pick nearest condition, assume optimal
            # linear scaling in slices and frequency (§4.7)
            (s0, f0), div0 = min(
                node.lat.items(),
                key=lambda kv: (abs(math.log(kv[0][0] / slices)),
                                abs(kv[0][1] - _fkey(f))))
            div = div0 * (s0 / slices) * (f0 / 100.0) / f
        return div / n_atoms + self.overhead

    def record_outcome(self, predicted: Optional[float], actual: float,
                       threshold: float = 50e-6):
        """Bench/eval hook: track misprediction rate (|err| > 50 us, §7.4)."""
        if predicted is None:
            return
        self.predictions += 1
        err = abs(predicted - actual)
        self.errors.append(err)
        if err > threshold:
            self.mispredictions += 1

    # -- DVFS support --------------------------------------------------------

    def runtime_weight(self, task: KernelTask) -> float:
        """Share of this node's runtime within its queue (the w in S=Σw·s)."""
        node = self.nodes.get(task.key())
        if node is None or node.total_runtime == 0:
            return 0.0
        qtotal = sum(n.total_runtime for (q, _), n in self.nodes.items()
                     if q == task.key()[0])
        return node.total_runtime / max(qtotal, 1e-12)
