"""Arch-config -> kernel-trace compiler + workload (client) specifications.

LithOS schedules opaque kernels; this module produces the kernel sequences a
driver-level interposer would observe when one of the assigned architectures
runs a training step / inference request.  Per-op FLOPs and HBM bytes are
derived analytically from the *real* architecture configs (the same ones the
JAX execution plane lowers), so the simulator's ground truth is parameterized
from first principles rather than fitted to the paper's curves.

Granularity: ``fusion`` controls how many consecutive ops share one kernel,
mirroring the difference between eager per-op launches (PyTorch) and fused
runtimes (TensorRT-LLM).  Fig-10-style long kernels arise naturally from big
batches / long prompts.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.types import FaultEvent, FaultPlan, KernelWork, Priority

DSIZE = 2               # bf16
TILE_M = TILE_N = 128   # matmul output tile per thread block
EW_TILE = 8192          # elements per elementwise block


@dataclass(frozen=True)
class OpDesc:
    """One operator-level kernel: name + ground-truth work terms.

    ``phase`` tags LLM serving phases ("prefill" | "decode" | "") so the
    control plane can treat compute-bound prefill and latency-critical
    memory-bound decode differently (atomization, pressure sampling).  Only
    the disaggregated LLM kinds tag it; legacy traces stay phase-less."""

    name: str
    flops: float
    bytes: float
    n_blocks: int
    phase: str = ""

    def work(self) -> KernelWork:
        return KernelWork(self.flops, self.bytes, self.n_blocks)


def tag_phase(ops: list[OpDesc], phase: str) -> list[OpDesc]:
    """Return a copy of ``ops`` with every op tagged as ``phase``."""
    return [replace(op, phase=phase) for op in ops]


def matmul_op(name: str, M: int, N: int, K: int, dsize: int = DSIZE) -> OpDesc:
    flops = 2.0 * M * N * K
    byts = float(dsize) * (M * K + K * N + M * N)
    blocks = math.ceil(M / TILE_M) * math.ceil(N / TILE_N)
    return OpDesc(name, flops, byts, max(1, blocks))


def ew_op(name: str, elems: float, *, streams: float = 3.0,
          flops_per_elem: float = 4.0, dsize: int = DSIZE) -> OpDesc:
    """Elementwise/normalization kernel: ``streams`` HBM passes over elems."""
    return OpDesc(name, flops_per_elem * elems, streams * elems * dsize,
                  max(1, math.ceil(elems / EW_TILE)))


def attention_op(name: str, B: int, Sq: int, Skv: int, n_q: int, n_kv: int,
                 hd: int, *, causal: bool, window: int = 0,
                 block_q: int = 512) -> OpDesc:
    if window:
        Skv_eff = min(Skv, window)
        causal_frac = 1.0
    else:
        Skv_eff = Skv
        causal_frac = 0.5 if (causal and Sq == Skv) else 1.0
    flops = 2.0 * 2.0 * B * n_q * Sq * Skv_eff * hd * causal_frac
    byts = DSIZE * B * (Sq * n_q * hd * 2 + Skv_eff * n_kv * hd * 2)
    blocks = B * n_q * math.ceil(Sq / block_q)
    return OpDesc(name, flops, byts, max(1, blocks))


def decode_attention_op(name: str, B: int, kv_len: int, n_q: int, n_kv: int,
                        hd: int, window: int = 0) -> OpDesc:
    """One-token attention against a KV cache — memory-bound by design."""
    kv_eff = min(kv_len, window) if window else kv_len
    flops = 2.0 * 2.0 * B * n_q * kv_eff * hd
    byts = DSIZE * B * kv_eff * n_kv * hd * 2 + DSIZE * B * n_q * hd * 2
    blocks = B * n_kv * max(1, math.ceil(kv_eff / 2048))
    return OpDesc(name, flops, byts, max(1, blocks))


# ---------------------------------------------------------------------------
# Per-block op sequences (forward)
# ---------------------------------------------------------------------------

def _mlp_ops(cfg: ArchConfig, T: int, tag: str) -> list[OpDesc]:
    d = cfg.d_model
    if cfg.moe is not None:
        m = cfg.moe
        ops = [matmul_op(f"{tag}.router", T, m.n_experts, d),
               ew_op(f"{tag}.dispatch", T * d, streams=4.0, flops_per_elem=1.0)]
        # routed experts as one grouped matmul over T*top_k tokens
        Tk = T * m.top_k
        ops += [matmul_op(f"{tag}.exp_wi", Tk, m.expert_d_ff, d),
                matmul_op(f"{tag}.exp_wg", Tk, m.expert_d_ff, d),
                ew_op(f"{tag}.exp_act", Tk * m.expert_d_ff, streams=3.0),
                matmul_op(f"{tag}.exp_wo", Tk, d, m.expert_d_ff),
                ew_op(f"{tag}.combine", T * d * m.top_k, streams=3.0,
                      flops_per_elem=2.0)]
        if m.n_shared_experts:
            ff = m.shared_d_ff * m.n_shared_experts
            ops += [matmul_op(f"{tag}.shared_wi", T, ff, d),
                    matmul_op(f"{tag}.shared_wg", T, ff, d),
                    matmul_op(f"{tag}.shared_wo", T, d, ff)]
        return ops
    glu = cfg.activation in ("swiglu", "geglu")
    ops = [matmul_op(f"{tag}.mlp_wi", T, cfg.d_ff, d)]
    if glu:
        ops.append(matmul_op(f"{tag}.mlp_wg", T, cfg.d_ff, d))
    ops.append(ew_op(f"{tag}.mlp_act", T * cfg.d_ff, streams=3.0 if glu else 2.0))
    ops.append(matmul_op(f"{tag}.mlp_wo", T, d, cfg.d_ff))
    return ops


def _attn_block_ops(cfg: ArchConfig, B: int, S: int, tag: str, *,
                    window: int = 0, kv_len: Optional[int] = None,
                    decode: bool = False) -> list[OpDesc]:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    T = B * S
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.qkv", T, (nq + 2 * nkv) * hd, d),
           ew_op(f"{tag}.rope", T * (nq + nkv) * hd, streams=2.0)]
    if decode:
        ops.append(decode_attention_op(
            f"{tag}.attn_dec", B, kv_len or S, nq, nkv, hd, window))
    else:
        ops.append(attention_op(f"{tag}.attn", B, S, kv_len or S, nq, nkv, hd,
                                causal=True, window=window))
    ops.append(matmul_op(f"{tag}.wo", T, d, nq * hd))
    ops.append(ew_op(f"{tag}.ln2", T * d))
    ops += _mlp_ops(cfg, T, tag)
    return ops


def _rec_block_ops(cfg: ArchConfig, B: int, S: int, tag: str,
                   decode: bool = False) -> list[OpDesc]:
    """RG-LRU block (RecurrentGemma): projections + conv + linear scan."""
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    T = B * S
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.rec_in", T, 2 * w, d),
           ew_op(f"{tag}.conv1d", T * w, streams=3.0,
                 flops_per_elem=2.0 * cfg.hybrid.conv_width),
           # the recurrence: memory-bound scan over the sequence
           ew_op(f"{tag}.lru_scan", T * w, streams=4.0, flops_per_elem=8.0),
           matmul_op(f"{tag}.rec_out", T, d, w),
           ew_op(f"{tag}.ln2", T * d)]
    ops += _mlp_ops(cfg, T, tag)
    return ops


def _mlstm_block_ops(cfg: ArchConfig, B: int, S: int, tag: str,
                     decode: bool = False) -> list[OpDesc]:
    d = cfg.d_model
    di = 2 * d                      # expansion
    hd = di // cfg.n_heads
    T = B * S
    chunk = 256 if not decode else 1
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.up", T, 2 * di, d),
           ew_op(f"{tag}.conv1d", T * di, streams=3.0, flops_per_elem=8.0),
           matmul_op(f"{tag}.qkv", T, 3 * di, di)]
    if decode:
        # recurrent state update: read/write C [B,H,hd,hd]
        state = B * cfg.n_heads * hd * hd
        ops.append(ew_op(f"{tag}.mlstm_step", state, streams=3.0,
                         flops_per_elem=6.0))
    else:
        # chunked parallel form: intra-chunk attention + inter-chunk state
        nchunk = math.ceil(S / chunk)
        intra = 2.0 * 2.0 * B * cfg.n_heads * nchunk * chunk * chunk * hd * 0.5
        inter = 4.0 * B * cfg.n_heads * nchunk * hd * hd * chunk
        byts = DSIZE * (3 * T * di + B * cfg.n_heads * nchunk * hd * hd * 2)
        blocks = B * cfg.n_heads * nchunk
        ops.append(OpDesc(f"{tag}.mlstm_chunk", intra + inter, byts,
                          max(1, blocks)))
    ops.append(matmul_op(f"{tag}.down", T, d, di))
    return ops


def _slstm_block_ops(cfg: ArchConfig, B: int, S: int, tag: str,
                     decode: bool = False) -> list[OpDesc]:
    d = cfg.d_model
    T = B * S
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.gates", T, 4 * d, d),
           # strictly sequential recurrence: S serial steps of B*d work;
           # expressed as a low-parallelism kernel (few blocks)
           OpDesc(f"{tag}.slstm_scan", 10.0 * T * d, 6.0 * T * d * DSIZE,
                  max(1, B * cfg.n_heads // 4)),
           matmul_op(f"{tag}.ffn_wi", T, cfg.d_ff or 4 * d, d),
           matmul_op(f"{tag}.ffn_wo", T, d, cfg.d_ff or 4 * d)]
    return ops


_BLOCK_OPS = {"attn": _attn_block_ops, "rec": _rec_block_ops,
              "mlstm": _mlstm_block_ops, "slstm": _slstm_block_ops}


def _pattern(cfg: ArchConfig) -> tuple[str, ...]:
    return cfg.hybrid.pattern if cfg.hybrid is not None else ("attn",)


def _block_ops(cfg, kind, B, S, tag, **kw):
    if kind == "attn":
        window = cfg.hybrid.window if cfg.hybrid is not None else 0
        return _attn_block_ops(cfg, B, S, tag, window=window, **kw)
    kw.pop("kv_len", None)
    return _BLOCK_OPS[kind](cfg, B, S, tag, decode=kw.get("decode", False))


# ---------------------------------------------------------------------------
# Whole-step traces
# ---------------------------------------------------------------------------

def forward_trace(cfg: ArchConfig, B: int, S: int, *,
                  with_head: bool = True) -> list[OpDesc]:
    T = B * S
    d = cfg.d_model
    ops = [ew_op("embed", T * d, streams=2.0, flops_per_elem=0.0)]
    pat = _pattern(cfg)
    for li in range(cfg.n_layers):
        kind = pat[li % len(pat)]
        ops += _block_ops(cfg, kind, B, S, f"L{li}.{kind}")
    ops.append(ew_op("final_norm", T * d))
    if with_head:
        ops.append(matmul_op("lm_head", T, cfg.vocab_size, d))
        ops.append(ew_op("softmax_xent", T * 8, streams=2.0, flops_per_elem=8.0))
    if cfg.is_encoder_decoder:
        # encoder stack over source frames + per-layer cross-attention
        Se = cfg.max_source_positions
        Te = B * Se
        for li in range(cfg.n_encoder_layers):
            ops += _attn_block_ops(cfg, B, Se, f"E{li}.attn")
        for li in range(cfg.n_layers):
            ops.append(attention_op(f"L{li}.xattn", B, S, Se, cfg.n_heads,
                                    cfg.n_heads, cfg.head_dim, causal=False))
    return ops


def train_step_trace(cfg: ArchConfig, B: int, S: int) -> list[OpDesc]:
    """fwd + bwd (2x matmul work as dgrad+wgrad) + optimizer update."""
    fwd = forward_trace(cfg, B, S)
    ops = list(fwd)
    for op in reversed(fwd):
        if ".attn" in op.name and "dec" not in op.name:
            ops.append(replace(op, name=op.name + ".bwd", flops=op.flops * 2.5,
                               bytes=op.bytes * 2.0))
        elif op.flops >= op.bytes:  # matmul-like: dgrad + wgrad
            ops.append(replace(op, name=op.name + ".dgrad"))
            ops.append(replace(op, name=op.name + ".wgrad"))
        else:
            ops.append(replace(op, name=op.name + ".bwd"))
    n_params = cfg.param_count()
    # grad reduce + AdamW update: read p,g,m,v write p,m,v
    ops.append(ew_op("optimizer", float(n_params), streams=6.0,
                     flops_per_elem=12.0))
    return ops


def prefill_trace(cfg: ArchConfig, B: int, S: int) -> list[OpDesc]:
    ops = forward_trace(cfg, B, S, with_head=False)
    ops.append(matmul_op("lm_head_last", B, cfg.vocab_size, cfg.d_model))
    return ops


def decode_step_trace(cfg: ArchConfig, B: int, kv_len: int) -> list[OpDesc]:
    d = cfg.d_model
    ops = [ew_op("embed", B * d, streams=2.0, flops_per_elem=0.0)]
    pat = _pattern(cfg)
    for li in range(cfg.n_layers):
        kind = pat[li % len(pat)]
        ops += _block_ops(cfg, kind, B, 1, f"L{li}.{kind}",
                          kv_len=kv_len, decode=True)
    ops.append(ew_op("final_norm", B * d))
    ops.append(matmul_op("lm_head", B, cfg.vocab_size, d))
    return ops


def fuse_trace(ops: list[OpDesc], group: int) -> list[OpDesc]:
    """Fuse consecutive ops ``group`` at a time (runtime-fused kernels)."""
    if group <= 1:
        return ops
    out = []
    for i in range(0, len(ops), group):
        g = ops[i:i + group]
        out.append(OpDesc(
            g[0].name + f"+f{len(g)}",
            sum(o.flops for o in g), sum(o.bytes for o in g),
            max(o.n_blocks for o in g), phase=g[0].phase))
    return out


# ---------------------------------------------------------------------------
# KV-cache footprint model (per-tenant memory the SliceMap/right-sizer
# must respect — LithOS-era tenants are compute-only; LLM decode holds HBM)
# ---------------------------------------------------------------------------

def kv_bytes_per_token(cfg: ArchConfig) -> float:
    """KV-cache bytes one cached token costs: K and V, every layer, at the
    KV-head width (GQA caches n_kv_heads, not n_heads)."""
    return 2.0 * cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * DSIZE


def kv_bytes(cfg: ArchConfig, batch: int, kv_len: int) -> float:
    """Total KV-cache footprint of ``batch`` requests each holding a
    ``kv_len``-token cache — the per-tenant memory term the right-sizer's
    floor clamp is derived from."""
    return float(batch) * float(kv_len) * kv_bytes_per_token(cfg)


def kv_floor_slices(cfg: ArchConfig, device, total_kv_bytes: float) -> int:
    """Minimum slice count whose pooled HBM capacity holds the footprint.

    A tenant right-sized below this would have nowhere to keep its cache:
    the clamp guarantees residency (weights/activations are out of scope —
    tenants are opaque kernel streams; DESIGN.md §10)."""
    if total_kv_bytes <= 0.0:
        return 1
    cap = getattr(device, "hbm_capacity", 0.0)
    if cap <= 0.0:
        return 1
    return min(device.n_slices, max(1, math.ceil(total_kv_bytes / cap)))


# ---------------------------------------------------------------------------
# Fault schedules (deterministic, seeded — the injection input to the
# fault-domain layer; see DESIGN.md §11)
# ---------------------------------------------------------------------------

def fault_schedule(n_devices: int, horizon: float, *, seed: int = 0,
                   n_device_dead: int = 0, n_slice_retired: int = 0,
                   n_transient: int = 0, slices_per_device: int = 1,
                   t_min_frac: float = 0.2, t_max_frac: float = 0.8,
                   stall_lo: float = 5e-3, stall_hi: float = 50e-3
                   ) -> FaultPlan:
    """Seeded random :class:`FaultPlan` over ``n_devices`` flat device
    positions — the generator benchmarks and property tests share.

    Fault times are uniform in ``[t_min_frac, t_max_frac] * horizon`` (the
    middle of the run, so there is work to disrupt and time to recover).
    Device deaths pick distinct devices; ``slice_retired`` and
    ``transient_stall`` events land on the *surviving* devices when any
    exist (faulting a device that is already scheduled to die tests
    nothing).  Deterministic in all arguments."""
    assert n_devices >= 1 and horizon > 0.0
    rng = np.random.default_rng((int(seed), n_devices, n_device_dead,
                                 n_slice_retired, n_transient))
    t = lambda: float(rng.uniform(t_min_frac, t_max_frac) * horizon)
    events: list[FaultEvent] = []
    n_dead = min(n_device_dead, n_devices)
    dead = sorted(rng.choice(n_devices, size=n_dead, replace=False).tolist()) \
        if n_dead else []
    for d in dead:
        events.append(FaultEvent(t=t(), kind="device_dead", member=int(d)))
    survivors = [d for d in range(n_devices) if d not in set(dead)]
    targets = survivors or list(range(n_devices))
    for _ in range(n_slice_retired):
        d = int(targets[rng.integers(len(targets))])
        sid = int(rng.integers(slices_per_device))
        events.append(FaultEvent(t=t(), kind="slice_retired", member=d,
                                 slice_id=sid))
    for _ in range(n_transient):
        d = int(targets[rng.integers(len(targets))])
        events.append(FaultEvent(t=t(), kind="transient_stall", member=d,
                                 duration=float(rng.uniform(stall_lo,
                                                            stall_hi))))
    return FaultPlan(tuple(sorted(events, key=lambda e: (e.t, e.member))))


# ---------------------------------------------------------------------------
# Client workload specs (what the simulator's clients replay)
# ---------------------------------------------------------------------------

#: memoized fused traces: (id(cfg), kind, shape...) -> (cfg, trace) — see
#: AppSpec.job_trace.  The entry pins the config object, so an id() can
#: never be recycled onto a different config while its trace is cached.
_trace_cache: dict = {}

#: normalized prompt-mix arrays keyed by the (hashable) mix tuple — the
#: np.array + normalize per draw showed up on million-request traces
_mix_cache: dict = {}


def sample_prompt_len(mix: tuple[tuple[int, float], ...],
                      rng: np.random.Generator) -> int:
    """One prompt-length draw from a mix — the single shared code path for
    every kind that samples ``prompt_mix`` (job_trace and the continuous
    client's arrival-time draw), so RNG streams stay identical no matter
    which engine or phase split consumes the request."""
    lp = _mix_cache.get(mix)
    if lp is None:
        lens, probs = zip(*mix)
        lp = _mix_cache[mix] = (lens, np.array(probs) / sum(probs))
    return int(rng.choice(lp[0], p=lp[1]))


# ---------------------------------------------------------------------------
# Continuous batching (llm_continuous): per-iteration batch recomposition
# ---------------------------------------------------------------------------

#: decode kv_len quantization for the shared iteration traces — keeps the
#: memoized trace population bounded while kv advances every token
KV_BUCKET = 64


def bucket_kv(kv_len: int) -> int:
    """Round a kv length up to the trace-memoization bucket."""
    return max(KV_BUCKET,
               ((int(kv_len) + KV_BUCKET - 1) // KV_BUCKET) * KV_BUCKET)


def continuous_prefill_trace(cfg: ArchConfig, S: int,
                             fusion: int) -> list[OpDesc]:
    """One joining request's prefill segment (B=1), phase-tagged, memoized.
    Shared across jobs — treat as read-only (the job_trace contract)."""
    key = (id(cfg), "cont_prefill", S, fusion)
    hit = _trace_cache.get(key)
    if hit is None:
        t = tag_phase(fuse_trace(prefill_trace(cfg, 1, S), fusion),
                      "prefill")
        _trace_cache[key] = (cfg, t)
        return t
    return hit[1]


def continuous_decode_trace(cfg: ArchConfig, B: int, kv_len: int,
                            fusion: int) -> list[OpDesc]:
    """One decode iteration over the running batch (``kv_len`` already
    bucketed by the caller), phase-tagged, memoized."""
    key = (id(cfg), "cont_decode", B, kv_len, fusion)
    hit = _trace_cache.get(key)
    if hit is None:
        t = tag_phase(fuse_trace(decode_step_trace(cfg, B, kv_len), fusion),
                      "decode")
        _trace_cache[key] = (cfg, t)
        return t
    return hit[1]


@dataclass
class Request:
    """One autoregressive request inside a continuous-batching tenant."""

    rid: int
    prompt_len: int
    decode_budget: int              # tokens to emit before leaving (>= 1)
    arrival: float
    kv_len: int = 0                 # cached tokens (0 until admitted)
    emitted: int = 0


class ContinuousBatchState:
    """Batch-composition state machine for one ``llm_continuous`` tenant.

    Requests arrive into ``waiting``; every iteration re-computes the
    running batch (waiting requests join up to ``max_batch``, exhausted
    requests leave), and each surviving request's ``kv_len`` advances by
    one emitted token.  All stochastic draws happen at arrival time (in
    the client's RNG stream — engine-parity safe); iteration transitions
    are purely deterministic functions of this state.

    Invariants (property-tested in tests/test_llm_workloads.py):
      * ``len(running) <= max_batch`` always;
      * per request, ``kv_len`` is monotone non-decreasing until eviction;
      * ``total_kv_bytes`` == sum of the running requests' kv footprints
        (KV bytes conservation across join/leave events).
    """

    def __init__(self, cfg: ArchConfig, max_batch: int):
        self.cfg = cfg
        self.max_batch = max(1, int(max_batch))
        self.per_token = kv_bytes_per_token(cfg)
        self.waiting: deque[Request] = deque()
        self.running: list[Request] = []
        self.iteration = 0
        self.total_kv_bytes = 0.0
        self.kv_peak_bytes = 0.0
        self.req_latencies: list[float] = []
        self.n_requests = 0
        self.n_completed = 0
        self._joiners: list[Request] = []
        self._decoders: list[Request] = []

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    @property
    def in_iteration(self) -> bool:
        return bool(self._joiners or self._decoders)

    def add_request(self, prompt_len: int, decode_budget: int,
                    arrival: float) -> Request:
        r = Request(self.n_requests, int(prompt_len),
                    max(1, int(decode_budget)), arrival)
        self.n_requests += 1
        self.waiting.append(r)
        return r

    def begin_iteration(self) -> tuple[list[Request], list[Request]]:
        """Recompute the batch composition for the next iteration.

        Returns ``(joiners, decoders)``: requests admitted this iteration
        (they prefill, writing their prompt into the KV cache) and requests
        already resident (they decode one token against their cache)."""
        assert not self.in_iteration, "iteration already open"
        self._decoders = list(self.running)
        while self.waiting and len(self.running) < self.max_batch:
            r = self.waiting.popleft()
            r.kv_len = r.prompt_len          # cache written during prefill
            self.total_kv_bytes += r.kv_len * self.per_token
            self.running.append(r)
            self._joiners.append(r)
        if self.total_kv_bytes > self.kv_peak_bytes:
            self.kv_peak_bytes = self.total_kv_bytes
        self.iteration += 1
        return list(self._joiners), list(self._decoders)

    def finish_iteration(self, now: float) -> list[Request]:
        """One token emitted per resident request: kv advances, exhausted
        requests leave (their KV bytes are reclaimed).  Returns leavers."""
        for r in self._decoders:
            r.kv_len += 1
            r.emitted += 1
            self.total_kv_bytes += self.per_token
        for r in self._joiners:
            r.kv_len += 1                    # prefill emits the first token
            r.emitted = 1
            self.total_kv_bytes += self.per_token
        self._joiners = []
        self._decoders = []
        done = [r for r in self.running if r.emitted >= r.decode_budget]
        if done:
            gone = set(id(r) for r in done)
            self.running = [r for r in self.running if id(r) not in gone]
            for r in done:
                self.total_kv_bytes -= r.kv_len * self.per_token
                self.req_latencies.append(now - r.arrival)
                self.n_completed += 1
        if self.total_kv_bytes > self.kv_peak_bytes:
            self.kv_peak_bytes = self.total_kv_bytes
        return done


@dataclass
class AppSpec:
    """One tenant: a model + load pattern + SLO + quota/priority."""

    name: str
    cfg: ArchConfig
    # "llm_infer" | "fwd_infer" | "train" | "llm_prefill" | "llm_decode"
    # | "llm_continuous" (disaggregated serving phases + continuous batching)
    kind: str
    priority: Priority = Priority.BEST_EFFORT
    quota_slices: int = 0
    # open-loop inference load
    rps: float = 0.0
    slo_latency: float = 0.0        # seconds; 0 => throughput-oriented
    batch: int = 1
    prompt_mix: tuple[tuple[int, float], ...] = ((512, 0.6), (2048, 0.3),
                                                 (8192, 0.1))
    decode_tokens: int = 32
    max_batch: int = 8              # llm_continuous: running-batch cap
    # train load (closed loop)
    train_batch: int = 8
    train_seq: int = 2048
    fusion: int = 6                 # ops fused per kernel in the trace
    seed: int = 0

    def job_trace(self, rng: np.random.Generator) -> list[OpDesc]:
        """One request (inference) or one step (training) as fused kernels.

        Trace construction is memoized on the deterministic shape key (the
        stochastic draws — prompt length, decode count — are taken from
        ``rng`` exactly as before, so random streams are unchanged).  On
        million-request traces every arrival used to rebuild an identical
        op list; now it is built once per distinct shape.  The returned
        list is shared across jobs and must be treated as read-only."""
        if self.kind == "train":
            key = (id(self.cfg), "train", self.train_batch, self.train_seq,
                   self.fusion)
            hit = _trace_cache.get(key)
            if hit is None:
                t = fuse_trace(train_step_trace(self.cfg, self.train_batch,
                                                self.train_seq), self.fusion)
                _trace_cache[key] = (self.cfg, t)
                return t
            return hit[1]
        S = sample_prompt_len(self.prompt_mix, rng)
        if self.kind == "fwd_infer":
            key = (id(self.cfg), "fwd", self.batch, S, self.fusion)
            hit = _trace_cache.get(key)
            if hit is None:
                t = fuse_trace(prefill_trace(self.cfg, self.batch, S),
                               self.fusion)
                _trace_cache[key] = (self.cfg, t)
                return t
            return hit[1]
        if self.kind == "llm_prefill":
            # disaggregated prefill tenant: one compute-bound prompt pass
            key = (id(self.cfg), "llm_prefill", self.batch, S, self.fusion)
            hit = _trace_cache.get(key)
            if hit is None:
                t = tag_phase(fuse_trace(prefill_trace(self.cfg, self.batch,
                                                       S), self.fusion),
                              "prefill")
                _trace_cache[key] = (self.cfg, t)
                return t
            return hit[1]
        n_out = max(1, int(rng.geometric(1.0 / self.decode_tokens)))
        n_out = min(n_out, 4 * self.decode_tokens)
        if self.kind == "llm_decode":
            # disaggregated decode tenant: the prompt is already cached
            # (prefill ran elsewhere); n_out memory-bound token steps.
            key = (id(self.cfg), "llm_decode", self.batch, S, n_out,
                   self.fusion)
            hit = _trace_cache.get(key)
            if hit is None:
                step = decode_step_trace(self.cfg, self.batch,
                                         S + n_out // 2)
                ops: list[OpDesc] = []
                for _ in range(n_out):
                    ops += step
                t = tag_phase(fuse_trace(ops, self.fusion), "decode")
                _trace_cache[key] = (self.cfg, t)
                return t
            return hit[1]
        if self.kind == "llm_continuous":
            # Demand-estimation proxy ONLY (mean_demand / routers): one
            # request's worth of work at B=1.  Real jobs are built per
            # iteration by the client from ContinuousBatchState — never
            # from this trace.
            key = (id(self.cfg), "llm_cont_proxy", S, n_out, self.fusion)
            hit = _trace_cache.get(key)
            if hit is None:
                ops = tag_phase(prefill_trace(self.cfg, 1, S), "prefill")
                step = tag_phase(decode_step_trace(self.cfg, 1,
                                                   S + n_out // 2), "decode")
                for _ in range(n_out):
                    ops += step
                t = fuse_trace(ops, self.fusion)
                _trace_cache[key] = (self.cfg, t)
                return t
            return hit[1]
        key = (id(self.cfg), "llm", self.batch, S, n_out, self.fusion)
        hit = _trace_cache.get(key)
        if hit is None:
            ops = prefill_trace(self.cfg, self.batch, S)
            step = decode_step_trace(self.cfg, self.batch, S + n_out // 2)
            for _ in range(n_out):
                ops += step
            t = fuse_trace(ops, self.fusion)
            _trace_cache[key] = (self.cfg, t)
            return t
        return hit[1]

    def arrivals(self, horizon: float, rng: np.random.Generator) -> list[float]:
        """Whole arrival stream for one client, generated in one batch:
        Poisson count, then sorted uniform order statistics.  np.sort keeps
        the historical ``sorted(...)`` result bit-for-bit (same draws, same
        total order on floats) while scaling to million-request traces."""
        if self.kind == "train" or self.rps <= 0:
            return []               # closed loop
        n = rng.poisson(self.rps * horizon)
        return np.sort(rng.uniform(0.0, horizon, n)).tolist()


def mean_demand(spec: AppSpec, device, n_samples: int = 5,
                seed: int = 0) -> float:
    """Mean full-device service seconds per job — used to calibrate Poisson
    loads to a target utilization (the paper tunes loads for ~80% HP util)."""
    from repro.core.costmodel import CostModel
    cost = CostModel(device)
    rng = np.random.default_rng((seed, spec.seed))
    tot = 0.0
    for _ in range(n_samples):
        for op in spec.job_trace(rng):
            tot += cost.latency(op.work(), device.n_slices)
    return tot / n_samples


def cluster_trace_apps(cfg: ArchConfig, device, *, n_services: int,
                       total_requests: int, target_util: float = 0.85,
                       n_devices: int = 1, be_per_device: int = 0,
                       be_cfg: Optional[ArchConfig] = None,
                       be_train_batch: int = 2, be_train_seq: int = 512,
                       quota_slices: int = 0,
                       name_prefix: str = "svc") -> tuple[list[AppSpec], float]:
    """Cluster-scale tenant population for the vectorized engine.

    ``n_services`` identical open-loop HIGH-priority inference tenants
    (``cfg`` fwd_infer, fusion=64 -> few kernels/request) whose aggregate
    offered load is calibrated to ``target_util * n_devices`` device-seconds
    per second on ``device`` — the same cost-model calibration the
    single-device throughput bench uses, scaled to a fleet — plus
    ``be_per_device * n_devices`` closed-loop best-effort trainers (they
    soak leftover capacity, giving the stealing tiers something to move).
    The horizon is sized so the services offer ``total_requests`` requests
    in aggregate.  Returns ``(apps, horizon)``; apps are ordered services
    first, trainers last, each with a distinct workload seed."""
    proto = AppSpec("proto", cfg, "fwd_infer", priority=Priority.HIGH,
                    batch=2, fusion=64, prompt_mix=((128, 1.0),))
    demand = mean_demand(proto, device)      # device-seconds per request
    total_rps = target_util * n_devices / demand
    horizon = total_requests / total_rps
    rps = total_rps / n_services
    apps = [replace(proto, name=f"{name_prefix}{i}", rps=rps, seed=i,
                    quota_slices=quota_slices)
            for i in range(n_services)]
    bcfg = be_cfg if be_cfg is not None else cfg
    apps += [AppSpec(f"bet{j}", bcfg, "train",
                     priority=Priority.BEST_EFFORT,
                     train_batch=be_train_batch, train_seq=be_train_seq,
                     seed=n_services + j)
             for j in range(be_per_device * n_devices)]
    return apps, horizon
