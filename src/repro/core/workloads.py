"""Arch-config -> kernel-trace compiler + workload (client) specifications.

LithOS schedules opaque kernels; this module produces the kernel sequences a
driver-level interposer would observe when one of the assigned architectures
runs a training step / inference request.  Per-op FLOPs and HBM bytes are
derived analytically from the *real* architecture configs (the same ones the
JAX execution plane lowers), so the simulator's ground truth is parameterized
from first principles rather than fitted to the paper's curves.

Granularity: ``fusion`` controls how many consecutive ops share one kernel,
mirroring the difference between eager per-op launches (PyTorch) and fused
runtimes (TensorRT-LLM).  Fig-10-style long kernels arise naturally from big
batches / long prompts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.types import KernelWork, Priority

DSIZE = 2               # bf16
TILE_M = TILE_N = 128   # matmul output tile per thread block
EW_TILE = 8192          # elements per elementwise block


@dataclass(frozen=True)
class OpDesc:
    """One operator-level kernel: name + ground-truth work terms."""

    name: str
    flops: float
    bytes: float
    n_blocks: int

    def work(self) -> KernelWork:
        return KernelWork(self.flops, self.bytes, self.n_blocks)


def matmul_op(name: str, M: int, N: int, K: int, dsize: int = DSIZE) -> OpDesc:
    flops = 2.0 * M * N * K
    byts = float(dsize) * (M * K + K * N + M * N)
    blocks = math.ceil(M / TILE_M) * math.ceil(N / TILE_N)
    return OpDesc(name, flops, byts, max(1, blocks))


def ew_op(name: str, elems: float, *, streams: float = 3.0,
          flops_per_elem: float = 4.0, dsize: int = DSIZE) -> OpDesc:
    """Elementwise/normalization kernel: ``streams`` HBM passes over elems."""
    return OpDesc(name, flops_per_elem * elems, streams * elems * dsize,
                  max(1, math.ceil(elems / EW_TILE)))


def attention_op(name: str, B: int, Sq: int, Skv: int, n_q: int, n_kv: int,
                 hd: int, *, causal: bool, window: int = 0,
                 block_q: int = 512) -> OpDesc:
    if window:
        Skv_eff = min(Skv, window)
        causal_frac = 1.0
    else:
        Skv_eff = Skv
        causal_frac = 0.5 if (causal and Sq == Skv) else 1.0
    flops = 2.0 * 2.0 * B * n_q * Sq * Skv_eff * hd * causal_frac
    byts = DSIZE * B * (Sq * n_q * hd * 2 + Skv_eff * n_kv * hd * 2)
    blocks = B * n_q * math.ceil(Sq / block_q)
    return OpDesc(name, flops, byts, max(1, blocks))


def decode_attention_op(name: str, B: int, kv_len: int, n_q: int, n_kv: int,
                        hd: int, window: int = 0) -> OpDesc:
    """One-token attention against a KV cache — memory-bound by design."""
    kv_eff = min(kv_len, window) if window else kv_len
    flops = 2.0 * 2.0 * B * n_q * kv_eff * hd
    byts = DSIZE * B * kv_eff * n_kv * hd * 2 + DSIZE * B * n_q * hd * 2
    blocks = B * n_kv * max(1, math.ceil(kv_eff / 2048))
    return OpDesc(name, flops, byts, max(1, blocks))


# ---------------------------------------------------------------------------
# Per-block op sequences (forward)
# ---------------------------------------------------------------------------

def _mlp_ops(cfg: ArchConfig, T: int, tag: str) -> list[OpDesc]:
    d = cfg.d_model
    if cfg.moe is not None:
        m = cfg.moe
        ops = [matmul_op(f"{tag}.router", T, m.n_experts, d),
               ew_op(f"{tag}.dispatch", T * d, streams=4.0, flops_per_elem=1.0)]
        # routed experts as one grouped matmul over T*top_k tokens
        Tk = T * m.top_k
        ops += [matmul_op(f"{tag}.exp_wi", Tk, m.expert_d_ff, d),
                matmul_op(f"{tag}.exp_wg", Tk, m.expert_d_ff, d),
                ew_op(f"{tag}.exp_act", Tk * m.expert_d_ff, streams=3.0),
                matmul_op(f"{tag}.exp_wo", Tk, d, m.expert_d_ff),
                ew_op(f"{tag}.combine", T * d * m.top_k, streams=3.0,
                      flops_per_elem=2.0)]
        if m.n_shared_experts:
            ff = m.shared_d_ff * m.n_shared_experts
            ops += [matmul_op(f"{tag}.shared_wi", T, ff, d),
                    matmul_op(f"{tag}.shared_wg", T, ff, d),
                    matmul_op(f"{tag}.shared_wo", T, d, ff)]
        return ops
    glu = cfg.activation in ("swiglu", "geglu")
    ops = [matmul_op(f"{tag}.mlp_wi", T, cfg.d_ff, d)]
    if glu:
        ops.append(matmul_op(f"{tag}.mlp_wg", T, cfg.d_ff, d))
    ops.append(ew_op(f"{tag}.mlp_act", T * cfg.d_ff, streams=3.0 if glu else 2.0))
    ops.append(matmul_op(f"{tag}.mlp_wo", T, d, cfg.d_ff))
    return ops


def _attn_block_ops(cfg: ArchConfig, B: int, S: int, tag: str, *,
                    window: int = 0, kv_len: Optional[int] = None,
                    decode: bool = False) -> list[OpDesc]:
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    T = B * S
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.qkv", T, (nq + 2 * nkv) * hd, d),
           ew_op(f"{tag}.rope", T * (nq + nkv) * hd, streams=2.0)]
    if decode:
        ops.append(decode_attention_op(
            f"{tag}.attn_dec", B, kv_len or S, nq, nkv, hd, window))
    else:
        ops.append(attention_op(f"{tag}.attn", B, S, kv_len or S, nq, nkv, hd,
                                causal=True, window=window))
    ops.append(matmul_op(f"{tag}.wo", T, d, nq * hd))
    ops.append(ew_op(f"{tag}.ln2", T * d))
    ops += _mlp_ops(cfg, T, tag)
    return ops


def _rec_block_ops(cfg: ArchConfig, B: int, S: int, tag: str,
                   decode: bool = False) -> list[OpDesc]:
    """RG-LRU block (RecurrentGemma): projections + conv + linear scan."""
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    T = B * S
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.rec_in", T, 2 * w, d),
           ew_op(f"{tag}.conv1d", T * w, streams=3.0,
                 flops_per_elem=2.0 * cfg.hybrid.conv_width),
           # the recurrence: memory-bound scan over the sequence
           ew_op(f"{tag}.lru_scan", T * w, streams=4.0, flops_per_elem=8.0),
           matmul_op(f"{tag}.rec_out", T, d, w),
           ew_op(f"{tag}.ln2", T * d)]
    ops += _mlp_ops(cfg, T, tag)
    return ops


def _mlstm_block_ops(cfg: ArchConfig, B: int, S: int, tag: str,
                     decode: bool = False) -> list[OpDesc]:
    d = cfg.d_model
    di = 2 * d                      # expansion
    hd = di // cfg.n_heads
    T = B * S
    chunk = 256 if not decode else 1
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.up", T, 2 * di, d),
           ew_op(f"{tag}.conv1d", T * di, streams=3.0, flops_per_elem=8.0),
           matmul_op(f"{tag}.qkv", T, 3 * di, di)]
    if decode:
        # recurrent state update: read/write C [B,H,hd,hd]
        state = B * cfg.n_heads * hd * hd
        ops.append(ew_op(f"{tag}.mlstm_step", state, streams=3.0,
                         flops_per_elem=6.0))
    else:
        # chunked parallel form: intra-chunk attention + inter-chunk state
        nchunk = math.ceil(S / chunk)
        intra = 2.0 * 2.0 * B * cfg.n_heads * nchunk * chunk * chunk * hd * 0.5
        inter = 4.0 * B * cfg.n_heads * nchunk * hd * hd * chunk
        byts = DSIZE * (3 * T * di + B * cfg.n_heads * nchunk * hd * hd * 2)
        blocks = B * cfg.n_heads * nchunk
        ops.append(OpDesc(f"{tag}.mlstm_chunk", intra + inter, byts,
                          max(1, blocks)))
    ops.append(matmul_op(f"{tag}.down", T, d, di))
    return ops


def _slstm_block_ops(cfg: ArchConfig, B: int, S: int, tag: str,
                     decode: bool = False) -> list[OpDesc]:
    d = cfg.d_model
    T = B * S
    ops = [ew_op(f"{tag}.ln1", T * d),
           matmul_op(f"{tag}.gates", T, 4 * d, d),
           # strictly sequential recurrence: S serial steps of B*d work;
           # expressed as a low-parallelism kernel (few blocks)
           OpDesc(f"{tag}.slstm_scan", 10.0 * T * d, 6.0 * T * d * DSIZE,
                  max(1, B * cfg.n_heads // 4)),
           matmul_op(f"{tag}.ffn_wi", T, cfg.d_ff or 4 * d, d),
           matmul_op(f"{tag}.ffn_wo", T, d, cfg.d_ff or 4 * d)]
    return ops


_BLOCK_OPS = {"attn": _attn_block_ops, "rec": _rec_block_ops,
              "mlstm": _mlstm_block_ops, "slstm": _slstm_block_ops}


def _pattern(cfg: ArchConfig) -> tuple[str, ...]:
    return cfg.hybrid.pattern if cfg.hybrid is not None else ("attn",)


def _block_ops(cfg, kind, B, S, tag, **kw):
    if kind == "attn":
        window = cfg.hybrid.window if cfg.hybrid is not None else 0
        return _attn_block_ops(cfg, B, S, tag, window=window, **kw)
    kw.pop("kv_len", None)
    return _BLOCK_OPS[kind](cfg, B, S, tag, decode=kw.get("decode", False))


# ---------------------------------------------------------------------------
# Whole-step traces
# ---------------------------------------------------------------------------

def forward_trace(cfg: ArchConfig, B: int, S: int, *,
                  with_head: bool = True) -> list[OpDesc]:
    T = B * S
    d = cfg.d_model
    ops = [ew_op("embed", T * d, streams=2.0, flops_per_elem=0.0)]
    pat = _pattern(cfg)
    for li in range(cfg.n_layers):
        kind = pat[li % len(pat)]
        ops += _block_ops(cfg, kind, B, S, f"L{li}.{kind}")
    ops.append(ew_op("final_norm", T * d))
    if with_head:
        ops.append(matmul_op("lm_head", T, cfg.vocab_size, d))
        ops.append(ew_op("softmax_xent", T * 8, streams=2.0, flops_per_elem=8.0))
    if cfg.is_encoder_decoder:
        # encoder stack over source frames + per-layer cross-attention
        Se = cfg.max_source_positions
        Te = B * Se
        for li in range(cfg.n_encoder_layers):
            ops += _attn_block_ops(cfg, B, Se, f"E{li}.attn")
        for li in range(cfg.n_layers):
            ops.append(attention_op(f"L{li}.xattn", B, S, Se, cfg.n_heads,
                                    cfg.n_heads, cfg.head_dim, causal=False))
    return ops


def train_step_trace(cfg: ArchConfig, B: int, S: int) -> list[OpDesc]:
    """fwd + bwd (2x matmul work as dgrad+wgrad) + optimizer update."""
    fwd = forward_trace(cfg, B, S)
    ops = list(fwd)
    for op in reversed(fwd):
        if ".attn" in op.name and "dec" not in op.name:
            ops.append(replace(op, name=op.name + ".bwd", flops=op.flops * 2.5,
                               bytes=op.bytes * 2.0))
        elif op.flops >= op.bytes:  # matmul-like: dgrad + wgrad
            ops.append(replace(op, name=op.name + ".dgrad"))
            ops.append(replace(op, name=op.name + ".wgrad"))
        else:
            ops.append(replace(op, name=op.name + ".bwd"))
    n_params = cfg.param_count()
    # grad reduce + AdamW update: read p,g,m,v write p,m,v
    ops.append(ew_op("optimizer", float(n_params), streams=6.0,
                     flops_per_elem=12.0))
    return ops


def prefill_trace(cfg: ArchConfig, B: int, S: int) -> list[OpDesc]:
    ops = forward_trace(cfg, B, S, with_head=False)
    ops.append(matmul_op("lm_head_last", B, cfg.vocab_size, cfg.d_model))
    return ops


def decode_step_trace(cfg: ArchConfig, B: int, kv_len: int) -> list[OpDesc]:
    d = cfg.d_model
    ops = [ew_op("embed", B * d, streams=2.0, flops_per_elem=0.0)]
    pat = _pattern(cfg)
    for li in range(cfg.n_layers):
        kind = pat[li % len(pat)]
        ops += _block_ops(cfg, kind, B, 1, f"L{li}.{kind}",
                          kv_len=kv_len, decode=True)
    ops.append(ew_op("final_norm", B * d))
    ops.append(matmul_op("lm_head", B, cfg.vocab_size, d))
    return ops


def fuse_trace(ops: list[OpDesc], group: int) -> list[OpDesc]:
    """Fuse consecutive ops ``group`` at a time (runtime-fused kernels)."""
    if group <= 1:
        return ops
    out = []
    for i in range(0, len(ops), group):
        g = ops[i:i + group]
        out.append(OpDesc(
            g[0].name + f"+f{len(g)}",
            sum(o.flops for o in g), sum(o.bytes for o in g),
            max(o.n_blocks for o in g)))
    return out


# ---------------------------------------------------------------------------
# Client workload specs (what the simulator's clients replay)
# ---------------------------------------------------------------------------

#: memoized fused traces: (id(cfg), kind, shape...) -> (cfg, trace) — see
#: AppSpec.job_trace.  The entry pins the config object, so an id() can
#: never be recycled onto a different config while its trace is cached.
_trace_cache: dict = {}

#: normalized prompt-mix arrays keyed by the (hashable) mix tuple — the
#: np.array + normalize per draw showed up on million-request traces
_mix_cache: dict = {}


@dataclass
class AppSpec:
    """One tenant: a model + load pattern + SLO + quota/priority."""

    name: str
    cfg: ArchConfig
    kind: str                       # "llm_infer" | "fwd_infer" | "train"
    priority: Priority = Priority.BEST_EFFORT
    quota_slices: int = 0
    # open-loop inference load
    rps: float = 0.0
    slo_latency: float = 0.0        # seconds; 0 => throughput-oriented
    batch: int = 1
    prompt_mix: tuple[tuple[int, float], ...] = ((512, 0.6), (2048, 0.3),
                                                 (8192, 0.1))
    decode_tokens: int = 32
    # train load (closed loop)
    train_batch: int = 8
    train_seq: int = 2048
    fusion: int = 6                 # ops fused per kernel in the trace
    seed: int = 0

    def job_trace(self, rng: np.random.Generator) -> list[OpDesc]:
        """One request (inference) or one step (training) as fused kernels.

        Trace construction is memoized on the deterministic shape key (the
        stochastic draws — prompt length, decode count — are taken from
        ``rng`` exactly as before, so random streams are unchanged).  On
        million-request traces every arrival used to rebuild an identical
        op list; now it is built once per distinct shape.  The returned
        list is shared across jobs and must be treated as read-only."""
        if self.kind == "train":
            key = (id(self.cfg), "train", self.train_batch, self.train_seq,
                   self.fusion)
            hit = _trace_cache.get(key)
            if hit is None:
                t = fuse_trace(train_step_trace(self.cfg, self.train_batch,
                                                self.train_seq), self.fusion)
                _trace_cache[key] = (self.cfg, t)
                return t
            return hit[1]
        mix = self.prompt_mix
        lp = _mix_cache.get(mix)
        if lp is None:
            lens, probs = zip(*mix)
            lp = _mix_cache[mix] = (lens, np.array(probs) / sum(probs))
        S = int(rng.choice(lp[0], p=lp[1]))
        if self.kind == "fwd_infer":
            key = (id(self.cfg), "fwd", self.batch, S, self.fusion)
            hit = _trace_cache.get(key)
            if hit is None:
                t = fuse_trace(prefill_trace(self.cfg, self.batch, S),
                               self.fusion)
                _trace_cache[key] = (self.cfg, t)
                return t
            return hit[1]
        n_out = max(1, int(rng.geometric(1.0 / self.decode_tokens)))
        n_out = min(n_out, 4 * self.decode_tokens)
        key = (id(self.cfg), "llm", self.batch, S, n_out, self.fusion)
        hit = _trace_cache.get(key)
        if hit is None:
            ops = prefill_trace(self.cfg, self.batch, S)
            step = decode_step_trace(self.cfg, self.batch, S + n_out // 2)
            for _ in range(n_out):
                ops += step
            t = fuse_trace(ops, self.fusion)
            _trace_cache[key] = (self.cfg, t)
            return t
        return hit[1]

    def arrivals(self, horizon: float, rng: np.random.Generator) -> list[float]:
        """Whole arrival stream for one client, generated in one batch:
        Poisson count, then sorted uniform order statistics.  np.sort keeps
        the historical ``sorted(...)`` result bit-for-bit (same draws, same
        total order on floats) while scaling to million-request traces."""
        if self.kind == "train" or self.rps <= 0:
            return []               # closed loop
        n = rng.poisson(self.rps * horizon)
        return np.sort(rng.uniform(0.0, horizon, n)).tolist()


def mean_demand(spec: AppSpec, device, n_samples: int = 5,
                seed: int = 0) -> float:
    """Mean full-device service seconds per job — used to calibrate Poisson
    loads to a target utilization (the paper tunes loads for ~80% HP util)."""
    from repro.core.costmodel import CostModel
    cost = CostModel(device)
    rng = np.random.default_rng((seed, spec.seed))
    tot = 0.0
    for _ in range(n_samples):
        for op in spec.job_trace(rng):
            tot += cost.latency(op.work(), device.n_slices)
    return tot / n_samples


def cluster_trace_apps(cfg: ArchConfig, device, *, n_services: int,
                       total_requests: int, target_util: float = 0.85,
                       n_devices: int = 1, be_per_device: int = 0,
                       be_cfg: Optional[ArchConfig] = None,
                       be_train_batch: int = 2, be_train_seq: int = 512,
                       quota_slices: int = 0,
                       name_prefix: str = "svc") -> tuple[list[AppSpec], float]:
    """Cluster-scale tenant population for the vectorized engine.

    ``n_services`` identical open-loop HIGH-priority inference tenants
    (``cfg`` fwd_infer, fusion=64 -> few kernels/request) whose aggregate
    offered load is calibrated to ``target_util * n_devices`` device-seconds
    per second on ``device`` — the same cost-model calibration the
    single-device throughput bench uses, scaled to a fleet — plus
    ``be_per_device * n_devices`` closed-loop best-effort trainers (they
    soak leftover capacity, giving the stealing tiers something to move).
    The horizon is sized so the services offer ``total_requests`` requests
    in aggregate.  Returns ``(apps, horizon)``; apps are ordered services
    first, trainers last, each with a distinct workload seed."""
    proto = AppSpec("proto", cfg, "fwd_infer", priority=Priority.HIGH,
                    batch=2, fusion=64, prompt_mix=((128, 1.0),))
    demand = mean_demand(proto, device)      # device-seconds per request
    total_rps = target_util * n_devices / demand
    horizon = total_requests / total_rps
    rps = total_rps / n_services
    apps = [replace(proto, name=f"{name_prefix}{i}", rps=rps, seed=i,
                    quota_slices=quota_slices)
            for i in range(n_services)]
    bcfg = be_cfg if be_cfg is not None else cfg
    apps += [AppSpec(f"bet{j}", bcfg, "train",
                     priority=Priority.BEST_EFFORT,
                     train_batch=be_train_batch, train_seq=be_train_seq,
                     seed=n_services + j)
             for j in range(be_per_device * n_devices)]
    return apps, horizon
