"""Launch-queue / client / job plumbing for the simulator.

Semantics mirror the CUDA stream model LithOS interposes on (§4.2):

* A *client* (tenant application) owns one launch queue (stream).
* Work arrives as *jobs* — one inference request or one training step.
* A job is a list of *batches*; each batch is a kernel sequence followed by
  an explicit sync event (the decode loop syncs every iteration to sample a
  token; training syncs per step).  Sync events delimit the predictor's
  ordinal indexing (§4.7).
* Within a queue kernels are strictly FIFO: kernel n+1 cannot start before
  kernel n completes (stream ordering).  Because dispatch happens exactly at
  the predecessor's completion instant and launch overhead is charged inside
  kernel latency, this is equivalent to a pipelined stream.

Open-loop clients (inference) have Poisson arrivals; closed-loop clients
(best-effort training) start the next job the moment the previous finishes.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.types import KernelTask, Priority
from repro.core.workloads import (AppSpec, ContinuousBatchState, OpDesc,
                                  bucket_kv, continuous_decode_trace,
                                  continuous_prefill_trace,
                                  sample_prompt_len)


@dataclass
class Batch:
    tasks: list[KernelTask]


@dataclass
class Job:
    batches: list[Batch]
    arrival: float
    jid: int
    t_finish: Optional[float] = None

    def n_kernels(self) -> int:
        return sum(len(b.tasks) for b in self.batches)


def _build_batches(ops: list[OpDesc], client_id: int, queue_id: int,
                   batch_marks: list[int], kids=None) -> list[Batch]:
    """Split an op list into batches at the given boundaries, assigning
    per-batch ordinals.  ``kids`` is the owning simulator's kernel-id
    stream (None falls back to the module-global one)."""
    batches, prev = [], 0
    for end in batch_marks + [len(ops)]:
        if end <= prev:
            continue
        tasks = []
        for i, op in enumerate(ops[prev:end]):
            extra = {} if kids is None else {"kid": next(kids)}
            tasks.append(KernelTask(op.name, op.work(), client_id=client_id,
                                    queue_id=queue_id, ordinal=i,
                                    phase=op.phase, **extra))
        batches.append(Batch(tasks))
        prev = end
    return batches


class Client:
    """One tenant: job generation + launch-queue state."""

    def __init__(self, cid: int, spec: AppSpec, horizon: float,
                 seed: int = 0):
        self.cid = cid
        self.spec = spec
        self.rng = np.random.default_rng((seed, spec.seed, cid))
        self.horizon = horizon
        self.pending: deque[Job] = deque()          # arrived, not started
        self.current: Optional[Job] = None
        self.batch_idx = 0
        self.kernel_idx = 0                          # next kernel within batch
        self.outstanding = 0                         # dispatched, incomplete
        self.completed: list[Job] = []
        self.jobs_issued = 0
        self.job_kernel_counts: list[int] = []   # kernels per issued job
        self.slice_seconds = 0.0
        self._arrivals = spec.arrivals(horizon, self.rng)
        # Continuous batching (llm_continuous): arrivals deliver *requests*
        # into this state machine; jobs are per-iteration batches built in
        # start_next_job.  None for every other kind.
        self.cbs: Optional[ContinuousBatchState] = (
            ContinuousBatchState(spec.cfg, spec.max_batch)
            if spec.kind == "llm_continuous" else None)
        # Live KV-cache footprint (bytes) — the scheduler's memory-floor
        # input (kv_floor_slices).  0 for tenants without a KV cache.
        self.kv_bytes = 0.0
        # Kernel-id stream: the owning simulator's, so kid assignment is a
        # per-simulator sequence no matter how several simulators' event
        # loops interleave (the hierarchy tiers' parity contract).
        self.kids = None
        # Engine hook (VecSimulator): notified after every queue-state
        # mutation so the engine can maintain incremental ready/startable
        # sets instead of scanning all clients per event.  None under the
        # reference engine (one attribute test per mutation, nothing more).
        self._watch = None
        # Lean-memory mode (collect_records=False): completed jobs drop
        # their batch/task objects — million-request traces would otherwise
        # retain every KernelTask ever executed.
        self._drop_batches = False

    # -- job generation -------------------------------------------------------

    @property
    def priority(self) -> Priority:
        return self.spec.priority

    def arrivals(self) -> list[float]:
        return self._arrivals

    def make_job(self, arrival: float) -> Job:
        ops = self.spec.job_trace(self.rng)
        # batch boundaries: decode-loop iterations sync individually.  The
        # trace builder emits prefill ops then repeated decode-step blocks;
        # for simplicity we sync per job for train/fwd and keep LLM decode
        # steps as separate batches via marker search on the "embed" op.
        marks: list[int] = []
        if self.spec.kind in ("llm_infer", "llm_decode"):
            marks = [i for i, op in enumerate(ops)
                     if i > 0 and op.name.startswith("embed")]
        self.jobs_issued += 1
        job = Job(_build_batches(ops, self.cid, self.cid, marks,
                                 kids=self.kids),
                  arrival, jid=self.jobs_issued)
        # record the *actual* kernels of each issued job: fractional-progress
        # metrics must divide by the sim's own traces, not resample them
        self.job_kernel_counts.append(job.n_kernels())
        return job

    def on_arrival(self, now: float):
        """One open-loop arrival: a *request* (continuous batching) or a
        whole job.  This is the single arrival entry point for both
        engines, so every stochastic draw happens here, in the client's
        own RNG stream, in arrival order — engine interleaving and
        prefill/decode phase splits cannot reorder the draws."""
        if self.cbs is not None:
            S = sample_prompt_len(self.spec.prompt_mix, self.rng)
            n_out = max(1, int(self.rng.geometric(
                1.0 / self.spec.decode_tokens)))
            n_out = min(n_out, 4 * self.spec.decode_tokens)
            self.cbs.add_request(S, n_out, now)
        elif self.spec.kind != "train":
            self.pending.append(self.make_job(now))
        self.start_next_job(now)      # train: the t=0 closed-loop kick

    def _make_iteration_job(self, now: float) -> Job:
        """One continuous-batching iteration as a job: a prefill segment
        per joining request (each its own batch — its own sync/ordinal
        space) followed by one fused decode step over the resident batch.
        Composition comes from ContinuousBatchState; no RNG draws here."""
        joiners, decoders = self.cbs.begin_iteration()
        cfg, fusion = self.spec.cfg, self.spec.fusion
        ops: list[OpDesc] = []
        marks: list[int] = []
        for r in joiners:
            if ops:
                marks.append(len(ops))
            ops = ops + continuous_prefill_trace(cfg, r.prompt_len, fusion)
        if decoders:
            if ops:
                marks.append(len(ops))
            mean_kv = (sum(r.kv_len for r in decoders)
                       + len(decoders) - 1) // len(decoders)
            ops = ops + continuous_decode_trace(cfg, len(decoders),
                                                bucket_kv(mean_kv), fusion)
        self.kv_bytes = self.cbs.total_kv_bytes
        self.jobs_issued += 1
        job = Job(_build_batches(ops, self.cid, self.cid, marks,
                                 kids=self.kids),
                  now, jid=self.jobs_issued)
        self.job_kernel_counts.append(job.n_kernels())
        return job

    # -- queue state ------------------------------------------------------------

    @property
    def closed_loop(self) -> bool:
        return self.spec.kind == "train" or self.spec.rps <= 0

    def _startable_now(self) -> bool:
        """Could start_next_job succeed right now?  The vec engine's
        incremental startable-set predicate — must mirror start_next_job
        exactly."""
        if self.current is not None:
            return False
        if self.cbs is not None:
            return self.cbs.has_work
        return bool(self.pending) or self.closed_loop

    def start_next_job(self, now: float) -> bool:
        if self.current is not None:
            return False
        if self.cbs is not None:
            if not self.cbs.has_work:
                return False
            self.current = self._make_iteration_job(now)
        elif self.pending:
            self.current = self.pending.popleft()
        elif self.closed_loop:
            self.current = self.make_job(now)
        else:
            return False
        self.batch_idx = 0
        self.kernel_idx = 0
        if self._watch is not None:
            self._watch._client_refresh(self)
        return True

    def peek(self) -> Optional[KernelTask]:
        """Next dispatchable kernel (strict FIFO: only when nothing is in
        flight for this queue)."""
        if self.current is None or self.outstanding > 0:
            return None
        b = self.current.batches[self.batch_idx]
        if self.kernel_idx < len(b.tasks):
            return b.tasks[self.kernel_idx]
        return None

    def pop(self) -> KernelTask:
        t = self.peek()
        assert t is not None
        self.kernel_idx += 1
        self.outstanding += 1
        if self._watch is not None:
            self._watch._client_refresh(self)
        return t

    def requeue(self, task: KernelTask):
        """Put a killed in-flight kernel back at the queue head (REEF-style
        reset preemption loses all progress)."""
        assert self.outstanding == 1
        self.outstanding -= 1
        self.kernel_idx -= 1
        b = self.current.batches[self.batch_idx]
        assert b.tasks[self.kernel_idx].kid == task.kid
        if self._watch is not None:
            self._watch._client_refresh(self)

    def undispatched_tasks(self):
        """Queued tasks not yet dispatched, in launch order — the queue
        contents that travel with the client on a migration.  (Completed
        tasks are excluded on purpose: completion records hold those very
        objects, so they must never be mutated.)"""
        if self.current is not None:
            b = self.current.batches[self.batch_idx]
            yield from b.tasks[self.kernel_idx:]
            for nb in self.current.batches[self.batch_idx + 1:]:
                yield from nb.tasks
        for j in self.pending:
            for b in j.batches:
                yield from b.tasks

    def kernel_done(self, now: float) -> bool:
        """Mark the in-flight kernel complete.  Returns True if this
        finished the whole job."""
        self.outstanding -= 1
        assert self.outstanding == 0
        done = False
        b = self.current.batches[self.batch_idx]
        if self.kernel_idx >= len(b.tasks):
            # batch done -> sync event -> next batch
            self.batch_idx += 1
            self.kernel_idx = 0
            if self.batch_idx >= len(self.current.batches):
                self.current.t_finish = now
                self.completed.append(self.current)
                if self._drop_batches:
                    self.current.batches = []
                self.current = None
                done = True
                if self.cbs is not None:
                    # iteration complete: one token per resident request,
                    # exhausted requests leave and their KV is reclaimed
                    # (before the watch refresh — has_work must be current)
                    self.cbs.finish_iteration(now)
                    self.kv_bytes = self.cbs.total_kv_bytes
        if self._watch is not None:
            self._watch._client_refresh(self)
        return done

    # -- metrics -----------------------------------------------------------------

    def latencies(self) -> list[float]:
        return [j.t_finish - j.arrival for j in self.completed]

    def req_latencies(self) -> list[float]:
        """Request-level latencies (arrival -> last token).  Continuous
        tenants only; job latencies() are per-iteration (TBT) there."""
        return list(self.cbs.req_latencies) if self.cbs is not None else []

    def kv_peak_bytes(self) -> float:
        return self.cbs.kv_peak_bytes if self.cbs is not None else 0.0

    def throughput(self, horizon: float) -> float:
        return len(self.completed) / horizon
