"""Launch-queue / client / job plumbing for the simulator.

Semantics mirror the CUDA stream model LithOS interposes on (§4.2):

* A *client* (tenant application) owns one launch queue (stream).
* Work arrives as *jobs* — one inference request or one training step.
* A job is a list of *batches*; each batch is a kernel sequence followed by
  an explicit sync event (the decode loop syncs every iteration to sample a
  token; training syncs per step).  Sync events delimit the predictor's
  ordinal indexing (§4.7).
* Within a queue kernels are strictly FIFO: kernel n+1 cannot start before
  kernel n completes (stream ordering).  Because dispatch happens exactly at
  the predecessor's completion instant and launch overhead is charged inside
  kernel latency, this is equivalent to a pipelined stream.

Open-loop clients (inference) have Poisson arrivals; closed-loop clients
(best-effort training) start the next job the moment the previous finishes.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.types import KernelTask, Priority
from repro.core.workloads import AppSpec, OpDesc


@dataclass
class Batch:
    tasks: list[KernelTask]


@dataclass
class Job:
    batches: list[Batch]
    arrival: float
    jid: int
    t_finish: Optional[float] = None

    def n_kernels(self) -> int:
        return sum(len(b.tasks) for b in self.batches)


def _build_batches(ops: list[OpDesc], client_id: int, queue_id: int,
                   batch_marks: list[int], kids=None) -> list[Batch]:
    """Split an op list into batches at the given boundaries, assigning
    per-batch ordinals.  ``kids`` is the owning simulator's kernel-id
    stream (None falls back to the module-global one)."""
    batches, prev = [], 0
    for end in batch_marks + [len(ops)]:
        if end <= prev:
            continue
        tasks = []
        for i, op in enumerate(ops[prev:end]):
            extra = {} if kids is None else {"kid": next(kids)}
            tasks.append(KernelTask(op.name, op.work(), client_id=client_id,
                                    queue_id=queue_id, ordinal=i, **extra))
        batches.append(Batch(tasks))
        prev = end
    return batches


class Client:
    """One tenant: job generation + launch-queue state."""

    def __init__(self, cid: int, spec: AppSpec, horizon: float,
                 seed: int = 0):
        self.cid = cid
        self.spec = spec
        self.rng = np.random.default_rng((seed, spec.seed, cid))
        self.horizon = horizon
        self.pending: deque[Job] = deque()          # arrived, not started
        self.current: Optional[Job] = None
        self.batch_idx = 0
        self.kernel_idx = 0                          # next kernel within batch
        self.outstanding = 0                         # dispatched, incomplete
        self.completed: list[Job] = []
        self.jobs_issued = 0
        self.job_kernel_counts: list[int] = []   # kernels per issued job
        self.slice_seconds = 0.0
        self._arrivals = spec.arrivals(horizon, self.rng)
        # Kernel-id stream: the owning simulator's, so kid assignment is a
        # per-simulator sequence no matter how several simulators' event
        # loops interleave (the hierarchy tiers' parity contract).
        self.kids = None
        # Engine hook (VecSimulator): notified after every queue-state
        # mutation so the engine can maintain incremental ready/startable
        # sets instead of scanning all clients per event.  None under the
        # reference engine (one attribute test per mutation, nothing more).
        self._watch = None
        # Lean-memory mode (collect_records=False): completed jobs drop
        # their batch/task objects — million-request traces would otherwise
        # retain every KernelTask ever executed.
        self._drop_batches = False

    # -- job generation -------------------------------------------------------

    @property
    def priority(self) -> Priority:
        return self.spec.priority

    def arrivals(self) -> list[float]:
        return self._arrivals

    def make_job(self, arrival: float) -> Job:
        ops = self.spec.job_trace(self.rng)
        # batch boundaries: decode-loop iterations sync individually.  The
        # trace builder emits prefill ops then repeated decode-step blocks;
        # for simplicity we sync per job for train/fwd and keep LLM decode
        # steps as separate batches via marker search on the "embed" op.
        marks: list[int] = []
        if self.spec.kind == "llm_infer":
            marks = [i for i, op in enumerate(ops)
                     if i > 0 and op.name.startswith("embed")]
        self.jobs_issued += 1
        job = Job(_build_batches(ops, self.cid, self.cid, marks,
                                 kids=self.kids),
                  arrival, jid=self.jobs_issued)
        # record the *actual* kernels of each issued job: fractional-progress
        # metrics must divide by the sim's own traces, not resample them
        self.job_kernel_counts.append(job.n_kernels())
        return job

    # -- queue state ------------------------------------------------------------

    @property
    def closed_loop(self) -> bool:
        return self.spec.kind == "train" or self.spec.rps <= 0

    def start_next_job(self, now: float) -> bool:
        if self.current is not None:
            return False
        if self.pending:
            self.current = self.pending.popleft()
        elif self.closed_loop:
            self.current = self.make_job(now)
        else:
            return False
        self.batch_idx = 0
        self.kernel_idx = 0
        if self._watch is not None:
            self._watch._client_refresh(self)
        return True

    def peek(self) -> Optional[KernelTask]:
        """Next dispatchable kernel (strict FIFO: only when nothing is in
        flight for this queue)."""
        if self.current is None or self.outstanding > 0:
            return None
        b = self.current.batches[self.batch_idx]
        if self.kernel_idx < len(b.tasks):
            return b.tasks[self.kernel_idx]
        return None

    def pop(self) -> KernelTask:
        t = self.peek()
        assert t is not None
        self.kernel_idx += 1
        self.outstanding += 1
        if self._watch is not None:
            self._watch._client_refresh(self)
        return t

    def requeue(self, task: KernelTask):
        """Put a killed in-flight kernel back at the queue head (REEF-style
        reset preemption loses all progress)."""
        assert self.outstanding == 1
        self.outstanding -= 1
        self.kernel_idx -= 1
        b = self.current.batches[self.batch_idx]
        assert b.tasks[self.kernel_idx].kid == task.kid
        if self._watch is not None:
            self._watch._client_refresh(self)

    def undispatched_tasks(self):
        """Queued tasks not yet dispatched, in launch order — the queue
        contents that travel with the client on a migration.  (Completed
        tasks are excluded on purpose: completion records hold those very
        objects, so they must never be mutated.)"""
        if self.current is not None:
            b = self.current.batches[self.batch_idx]
            yield from b.tasks[self.kernel_idx:]
            for nb in self.current.batches[self.batch_idx + 1:]:
                yield from nb.tasks
        for j in self.pending:
            for b in j.batches:
                yield from b.tasks

    def kernel_done(self, now: float) -> bool:
        """Mark the in-flight kernel complete.  Returns True if this
        finished the whole job."""
        self.outstanding -= 1
        assert self.outstanding == 0
        done = False
        b = self.current.batches[self.batch_idx]
        if self.kernel_idx >= len(b.tasks):
            # batch done -> sync event -> next batch
            self.batch_idx += 1
            self.kernel_idx = 0
            if self.batch_idx >= len(self.current.batches):
                self.current.t_finish = now
                self.completed.append(self.current)
                if self._drop_batches:
                    self.current.batches = []
                self.current = None
                done = True
        if self._watch is not None:
            self._watch._client_refresh(self)
        return done

    # -- metrics -----------------------------------------------------------------

    def latencies(self) -> list[float]:
        return [j.t_finish - j.arrival for j in self.completed]

    def throughput(self, horizon: float) -> float:
        return len(self.completed) / horizon
