"""§4.6 Transparent power management (DVFS governor).

Model: a kernel's relative slowdown at frequency f is approximated first-order
as ``k_obs = s * (f_max/f - 1)`` with per-kernel *sensitivity* s (1 = fully
compute-bound, 0 = fully memory-bound).  Aggregating over a stream with
runtime weights w gives ``S = Σ w·s``; bounding total slowdown by the latency
slip ``k`` yields the target ``f_final = f_max / (1 + k/S)``.

Conservative learning protocol (the paper's): unseen kernels run at f_max;
on first sight a kernel is *assumed linear* (s=1) which biases the target
high; observed slowdowns then refine s and allow lower frequencies.  Because
frequency switching is slow (~50 ms), the governor rate-limits transitions
and quantizes to the device's supported f-states.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.types import CompletionRecord, DeviceSpec, KernelTask


@dataclass
class SensitivityStats:
    s: float = 1.0                     # assumed linear until measured
    measured: bool = False
    runtime: float = 0.0               # cumulated runtime (weight numerator)
    base_lat: Optional[float] = None   # latency at f_max (per launch-unit)


class DVFSGovernor:
    def __init__(self, device: DeviceSpec, slip: float = 1.1,
                 switch_interval: float = 0.25):
        self.device = device
        self.k = max(slip - 1.0, 0.0)
        self.switch_interval = switch_interval
        self.stats: dict[tuple[int, int], SensitivityStats] = {}
        self.current_f = 1.0
        self.last_switch = -1e9
        self.switches = 0
        #: external frequency ceiling (cluster power manager).  1.0 = no cap
        #: — the governor behaves exactly as before the cluster tier existed.
        self.f_cap = 1.0

    # -- learning -----------------------------------------------------------

    def observe(self, rec: CompletionRecord):
        st = self.stats.setdefault(rec.task.key(), SensitivityStats())
        lat = rec.latency
        if rec.task.atom_of is not None:
            lat *= rec.task.atom_of[2]
        st.runtime += lat
        if rec.freq >= 0.999:
            # EWMA base latency at f_max
            st.base_lat = lat if st.base_lat is None else 0.7 * st.base_lat + 0.3 * lat
        elif st.base_lat:
            k_obs = lat / st.base_lat - 1.0
            denom = 1.0 / rec.freq - 1.0
            if denom > 1e-6:
                s = min(max(k_obs / denom, 0.0), 1.5)
                st.s = s if not st.measured else 0.7 * st.s + 0.3 * s
                st.measured = True

    # -- policy ---------------------------------------------------------------

    def aggregate_sensitivity(self, queue_id: Optional[int] = None) -> float:
        items = [(key, st) for key, st in self.stats.items()
                 if queue_id is None or key[0] == queue_id]
        total = sum(st.runtime for _, st in items)
        if total <= 0:
            return 1.0
        return sum(st.runtime / total * st.s for _, st in items)

    def _clamp(self, f: float) -> float:
        """Apply the external cap: highest supported state <= ``f_cap``."""
        if f <= self.f_cap + 1e-9:
            return f
        best = None
        for s in self.device.f_states:
            if s <= self.f_cap + 1e-9:
                best = s
        return best if best is not None else self.device.f_states[0]

    def target_frequency(self, queue_id: Optional[int] = None) -> float:
        """f_final = f_max / (1 + k/S), quantized down to a supported state,
        never above the cluster power manager's ``f_cap``."""
        if self.k <= 0:
            return self._clamp(1.0)
        S = self.aggregate_sensitivity(queue_id)
        if S <= 1e-6:
            raw = self.device.f_states[0]
        else:
            raw = 1.0 / (1.0 + self.k / S)
        # highest supported state <= is wrong direction: choose the lowest
        # state >= raw (conservative: never exceed the slip budget)
        for f in self.device.f_states:
            if f >= raw - 1e-9:
                return self._clamp(f)
        return self._clamp(1.0)

    def maybe_switch(self, now: float,
                     queue_id: Optional[int] = None) -> Optional[float]:
        """Returns the new frequency if the governor decides to switch."""
        if now - self.last_switch < self.switch_interval:
            return None
        f = self.target_frequency(queue_id)
        if abs(f - self.current_f) < 1e-9:
            return None
        self.current_f = f
        self.last_switch = now
        self.switches += 1
        return f

    def unseen(self, task: KernelTask) -> bool:
        return task.key() not in self.stats


def plan_power_budget(devices: list[DeviceSpec], active: list[int],
                      hp: list[bool], cap: float,
                      hp_floor: float = 0.75) -> list[float]:
    """Choose per-device frequency caps so the projected fleet power fits
    ``cap`` watts.  The cluster tier's planning half of §4.6: the per-device
    governor optimizes latency-vs-power locally, this allocates the global
    budget that bounds it.

    ``active`` is each device's busy-slice count and ``hp`` whether it
    currently runs HIGH-priority work.  Deterministic greedy waterfill: all
    devices start at f_max; repeatedly step down the frequency of the
    device with the largest marginal power saving (``active * p_dyn *
    (f^3 - f_next^3)``), considering best-effort-only devices first and
    never dropping a device with HP work below ``hp_floor``.  Stops when
    the projection fits or no step can save anything — static + idle floor
    power is not reducible by DVFS, so an infeasible cap degrades to
    every-knob-at-minimum rather than failing."""
    n = len(devices)
    idx = [len(d.f_states) - 1 for d in devices]    # start at f_max

    def freq(d):
        return devices[d].f_states[idx[d]]

    def floor_idx(d):
        if not hp[d]:
            return 0
        states = devices[d].f_states
        for i, s in enumerate(states):
            if s >= hp_floor - 1e-9:
                return i
        return len(states) - 1

    total = sum(devices[d].power(active[d], freq(d)) for d in range(n))
    while total > cap + 1e-9:
        best, best_save = None, 0.0
        for be_pass in (True, False):
            for d in range(n):
                if hp[d] == be_pass:        # BE devices on the first pass
                    continue
                if idx[d] <= floor_idx(d):
                    continue
                f0, f1 = freq(d), devices[d].f_states[idx[d] - 1]
                save = active[d] * devices[d].p_dyn * (f0 ** 3 - f1 ** 3)
                if save > best_save + 1e-12:
                    best, best_save = d, save
            if best is not None:
                break
        if best is None:
            break                           # cap below the static floor
        idx[best] -= 1
        total -= best_save
    return [freq(d) for d in range(n)]
