"""Core types for the LithOS control plane.

The control plane schedules *kernels* — opaque units of device work described
by the quantities a driver-level interposer can observe (grid size, launch
config) plus the ground-truth work terms (flops / HBM bytes) that only the
simulator's cost model sees.  The OS never reads ``flops``/``bytes`` directly;
it learns latencies online through the observation interface (§4.7).

GPU -> TPU mapping (DESIGN.md §2): the schedulable spatial unit is a
*core-slice* (one TPU chip/core of the pod-slice a host manages), standing in
for the paper's TPC.  All scheduler math is granularity-agnostic.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Optional


class Priority(IntEnum):
    BEST_EFFORT = 0
    HIGH = 1


@dataclass(frozen=True)
class DeviceSpec:
    """A schedulable device: a pod-slice of ``n_slices`` core-slices.

    Constants default to the TPU v5e numbers used throughout the roofline
    analysis (197 TFLOP/s bf16, 819 GB/s HBM per chip).  ``f_states`` are the
    supported frequency steps as fractions of f_max, mirroring a discrete
    DVFS ladder; ``f_switch_latency`` models the ~50 ms transition cost the
    paper measures on current hardware (§4.6).
    """

    n_slices: int = 64
    peak_flops: float = 197e12          # per slice, bf16
    hbm_bw: float = 819e9               # per slice, bytes/s
    hbm_capacity: float = 16e9          # per slice, bytes (v5e: 16 GB/chip)
    occupancy: int = 8                  # blocks resident per slice
    launch_overhead: float = 4e-6       # per kernel/atom dispatch, seconds
    # dense DVFS ladder (real GPUs step ~15 MHz; 2.5% of f_max here)
    f_states: tuple[float, ...] = tuple(
        round(0.40 + 0.025 * i, 3) for i in range(25))
    f_switch_latency: float = 50e-3
    # Power model per slice (watts): P = idle + dyn * (f/fmax)^3 * active
    p_idle: float = 60.0
    p_dyn: float = 140.0
    p_static_host: float = 120.0        # host/uncore, per device

    def power(self, active_slices: int, f: float) -> float:
        """Instantaneous device power draw (W)."""
        return (self.p_static_host
                + self.n_slices * self.p_idle
                + active_slices * self.p_dyn * (f ** 3))

    @classmethod
    def tpu_v5e_pod_slice(cls, n_chips: int = 64) -> "DeviceSpec":
        """TPU-native profile: schedulable unit = one v5e chip."""
        return cls(n_slices=n_chips)

    @classmethod
    def a100_like(cls) -> "DeviceSpec":
        """Paper-testbed-calibrated profile: one A100 (SXM4, 108 SMs = 54
        TPCs, 312 TFLOP/s bf16, 1.94 TB/s HBM, ~400 W TDP).  Used by the
        scheduling benchmarks so Table 1/2 batch sizes and Fig 10 kernel
        latencies land in the paper's regimes; the TPU profile is used by
        everything roofline-facing."""
        # power: ~60 W idle -> ~400 W loaded, 85% dynamic (A100 SXM4)
        return cls(n_slices=54,
                   peak_flops=312e12 / 54,
                   hbm_bw=1.94e12 / 54,
                   hbm_capacity=80e9 / 54,
                   occupancy=8,
                   launch_overhead=4e-6,
                   p_idle=0.4, p_dyn=6.3, p_static_host=40.0)

    @classmethod
    def l4_like(cls) -> "DeviceSpec":
        """Inference-tier profile: one L4 (Ada, 58 SMs = 29 TPCs, 121
        TFLOP/s dense fp16, 300 GB/s GDDR6, 72 W TDP).  Roughly half an
        A100's TPC count at a quarter of the power — the asymmetric-capacity
        member of heterogeneous nodes/clusters, where the fragmentation
        metric starts to bite (a guarantee that fits any A100 may fit no
        L4)."""
        # power: ~22 W idle -> ~72 W loaded (inference-tier card)
        return cls(n_slices=29,
                   peak_flops=121e12 / 29,
                   hbm_bw=300e9 / 29,
                   hbm_capacity=24e9 / 29,
                   occupancy=8,
                   launch_overhead=4e-6,
                   p_idle=0.25, p_dyn=1.7, p_static_host=15.0)


@dataclass(frozen=True)
class NodeSpec:
    """A multi-device node: N :class:`DeviceSpec`s behind one control plane.

    Each device runs its own policy instance (per-device quotas, slice maps,
    predictors); the node-level router (``repro.core.node``) places tenants
    across devices.  A 1-device node is exactly equivalent to scheduling the
    bare :class:`DeviceSpec` — the parity contract the node layer's tests
    enforce."""

    devices: tuple[DeviceSpec, ...]
    name: str = "node"

    def __post_init__(self):
        assert len(self.devices) >= 1, "a node needs at least one device"

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def total_slices(self) -> int:
        return sum(d.n_slices for d in self.devices)

    @classmethod
    def uniform(cls, n_devices: int,
                device: Optional[DeviceSpec] = None) -> "NodeSpec":
        dev = device if device is not None else DeviceSpec()
        return cls(devices=tuple(dev for _ in range(n_devices)),
                   name=f"{n_devices}x-node")


@dataclass(frozen=True)
class NodeConfig:
    """Node-level lending protocol knobs (cross-device TPC stealing).

    The NodeCoordinator samples per-device pressure every ``epoch`` seconds
    and, when one device is saturated while another is idle, migrates one
    best-effort client's launch queue from the saturated device to the idle
    one (drained at a kernel boundary, charged ``migration_cost`` of
    dispatch blackout on arrival).

    Pressure signal, per device:
      * HP queue depth — jobs pending or in progress across HIGH-priority
        clients (saturated when >= ``hp_depth_hi``), and
      * SliceMap free-list occupancy — idle-slice fraction (saturated when
        <= ``free_lo`` with 2+ active tenants contending; a lender when
        >= ``free_hi`` with no HP backlog).

    ``migration=False`` (the default) is the exact-parity contract: the
    coordinator never intervenes and the node behaves bit-for-bit like
    independent per-device runs."""

    migration: bool = False
    epoch: float = 0.25             # pressure sampling period, seconds
    hp_depth_hi: int = 2            # HP backlog >= this => saturated
    free_lo: float = 0.125          # idle fraction <= this (contended) => saturated
    free_hi: float = 0.5            # idle fraction >= this + no HP backlog => lender
    migration_cost: float = 0.05    # seconds of dispatch blackout per move
    cooldown: float = 1.0           # per-client quiet period between moves
    max_migrations: int = 0         # total cap; 0 = unbounded
    validate: bool = False          # run cross-device conservation checks
                                    # at every epoch (tests)


@dataclass(frozen=True)
class ClusterSpec:
    """A cluster: N :class:`NodeSpec`s behind one placement/power plane.

    Each node runs its own :class:`~repro.core.node.NodeCoordinator` (own
    routers, lending protocol, per-device policies); the cluster tier
    places tenants onto nodes, optionally migrates best-effort tenants
    between nodes, and coordinates per-device DVFS f-states under a
    cluster-wide power cap.  A 1-node cluster is exactly equivalent to
    evaluating the bare :class:`NodeSpec` — the parity contract the cluster
    layer's tests enforce, one level up from the node<->device one."""

    nodes: tuple[NodeSpec, ...]
    name: str = "cluster"

    def __post_init__(self):
        assert len(self.nodes) >= 1, "a cluster needs at least one node"

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_devices(self) -> int:
        return sum(n.n_devices for n in self.nodes)

    @property
    def total_slices(self) -> int:
        return sum(n.total_slices for n in self.nodes)

    @classmethod
    def uniform(cls, n_nodes: int,
                node: Optional[NodeSpec] = None) -> "ClusterSpec":
        nd = node if node is not None else NodeSpec.uniform(2)
        return cls(nodes=tuple(nd for _ in range(n_nodes)),
                   name=f"{n_nodes}x-cluster")


@dataclass(frozen=True)
class ClusterConfig:
    """Cluster-tier knobs: the same lending-protocol field names as
    :class:`NodeConfig` (the level-agnostic coordinator reads either), at
    node granularity, plus the cluster power budget.

    Pressure is aggregated per node (summed HP backlog, pooled free-list
    occupancy), epochs are coarser and migrations costlier than the node
    tier's — cross-node moves ship a replica's working state over the
    fabric, not NVLink.  ``power_cap`` (watts; 0 = uncapped) bounds the
    projected cluster draw: at every epoch the power manager lowers
    per-device DVFS f-states — best-effort-only devices first, HP devices
    never below ``power_hp_floor`` — until the projection fits the cap.

    ``node_config`` is applied to every member node's own coordinator
    (intra-node stealing composes with cluster-level migration: the frozen
    set keeps the two tiers off the same client)."""

    migration: bool = False
    epoch: float = 0.5              # pressure sampling period, seconds
    hp_depth_hi: int = 4            # node-aggregate HP backlog => saturated
    free_lo: float = 0.125          # pooled idle fraction <= this => saturated
    free_hi: float = 0.5            # pooled idle fraction >= this => lender
    migration_cost: float = 0.25    # seconds of dispatch blackout per move
    cooldown: float = 2.0           # per-client quiet period between moves
    max_migrations: int = 0         # total cap; 0 = unbounded
    validate: bool = False          # run cluster-wide conservation checks
                                    # at every epoch (tests)
    power_cap: float = 0.0          # cluster power budget, watts; 0 = off
    power_hp_floor: float = 0.75    # min f-state for devices with HP work
    node_config: Optional[NodeConfig] = None  # per-node coordinator knobs


#: fault kinds a :class:`FaultPlan` may schedule
FAULT_KINDS = ("device_dead", "slice_retired", "transient_stall")


@dataclass(frozen=True)
class FaultEvent:
    """One injected hardware fault, scheduled at simulated time ``t``.

    ``member`` is a flat leaf-device index at whatever scope the plan is
    handed to (device 0 for a bare :class:`DeviceSpec` run, the node's
    device index for a :class:`NodeSpec`, the cluster-flat device index for
    a :class:`ClusterSpec`).

    Kinds:
      * ``device_dead``     — the whole device fails permanently; its
        tenants must be evacuated by the tier above.
      * ``slice_retired``   — ECC-style loss of one TPC slice
        (``slice_id``); the device keeps running at reduced capacity.
      * ``transient_stall`` — every in-flight kernel on the device is
        delayed by ``duration`` seconds (SXid-style recoverable hiccup).
    """

    t: float
    kind: str
    member: int = 0
    slice_id: int = -1              # slice_retired only
    duration: float = 0.0           # transient_stall only

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(choose from {FAULT_KINDS})")
        if self.t < 0.0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")
        if self.kind == "slice_retired" and self.slice_id < 0:
            raise ValueError("slice_retired needs a slice_id")
        if self.kind == "transient_stall" and not self.duration > 0.0:
            raise ValueError("transient_stall needs a duration > 0")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of :class:`FaultEvent`s.

    The plan is the single source of failure truth for a run: the same
    plan replayed against either simulator engine injects byte-identical
    event streams.  An empty plan is the no-fault contract — zero extra
    heap events, bit-for-bit identical to a run with no plan at all."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def events_for(self, member: int) -> tuple[FaultEvent, ...]:
        """This plan's events targeting one flat device index, time-sorted
        (ties kept in plan order — deterministic)."""
        return tuple(sorted((e for e in self.events if e.member == member),
                            key=lambda e: e.t))

    @property
    def dead_members(self) -> tuple[int, ...]:
        return tuple(sorted({e.member for e in self.events
                             if e.kind == "device_dead"}))


_kernel_ids = itertools.count()


def reset_kernel_ids():
    """Restart the global kernel-id counter (parity tests only).

    Kernel ids are drawn from one process-global counter, so two runs of
    the same scenario in one process get different ``kid`` values.  The
    engine-parity tests reset the counter before each run so the reference
    and vectorized engines produce byte-identical CompletionRecord streams,
    kids included."""
    global _kernel_ids
    _kernel_ids = itertools.count()


@dataclass
class KernelWork:
    """Ground-truth work terms (cost-model facts, hidden from the OS).

    ``flops``     total floating-point work
    ``bytes``     total HBM traffic
    ``n_blocks``  grid size (schedulable tiles — the atomizer's unit)
    """

    flops: float
    bytes: float
    n_blocks: int

    def scaled(self, frac: float) -> "KernelWork":
        nb = max(1, round(self.n_blocks * frac))
        return KernelWork(self.flops * frac, self.bytes * frac, nb)


@dataclass
class KernelTask:
    """One kernel launch as seen at the interposition boundary.

    ``op_name``/``ordinal`` identify the operator node in the model's DFG:
    the predictor keys on (queue, ordinal) because a single kernel function
    serves layers with different tensor sizes (§4.7).
    """

    op_name: str
    work: KernelWork
    client_id: int = 0
    queue_id: int = 0
    ordinal: int = -1                   # k-th kernel since last sync event
    kid: int = field(default_factory=lambda: next(_kernel_ids))
    # Set by the atomizer: (parent kid, atom index, n_atoms).
    atom_of: Optional[tuple[int, int, int]] = None
    # LLM serving phase: "prefill" (compute-bound, atomize like training) |
    # "decode" (memory-bound, already sub-quantum — never atomized) | ""
    # (phase-agnostic legacy kernel).  Carried from the workload trace.
    phase: str = ""

    @property
    def is_atom(self) -> bool:
        return self.atom_of is not None

    def key(self) -> tuple[int, int]:
        """Predictor identity: operator node = (queue, ordinal)."""
        return (self.queue_id, self.ordinal)


@dataclass
class SyncEvent:
    """Explicit synchronization (cuStreamSynchronize analogue).

    Delimits batches for the predictor's ordinal indexing and is the point
    where the client blocks until its outstanding work completes.
    """

    client_id: int
    queue_id: int


@dataclass
class Quota:
    """Per-client compute quota: guaranteed core-slices when work is
    available (§4.2), plus scheduling priority."""

    slices: int
    priority: Priority = Priority.BEST_EFFORT


@dataclass
class CompletionRecord:
    """What the OS observes when a kernel/atom completes — the only channel
    through which predictor / right-sizer / DVFS learn."""

    task: KernelTask
    t_submit: float
    t_start: float
    t_end: float
    slices: int
    freq: float                         # fraction of f_max during execution

    @property
    def latency(self) -> float:
        return self.t_end - self.t_start

    @property
    def queueing(self) -> float:
        return self.t_start - self.t_submit
