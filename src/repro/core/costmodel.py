"""Ground-truth latency model for the discrete-event simulator.

The simulator executes :class:`KernelTask`s on a :class:`DeviceSpec` whose
latencies come from a first-principles roofline model — *not* from fitting
the paper's curves — so the OS policies (which only observe completions)
face the same learning problem as on real hardware.

Latency of a kernel on ``t`` slices at relative frequency ``f``::

    t_eff  = min(t, ceil(n_blocks / occupancy))        # parallelism bound
    waves  = ceil(n_blocks / (t_eff * occupancy))      # wave quantization
    c_time = flops / (t_eff*occ_waves ... )            -- expressed per-wave:
    per-block compute = flops/n_blocks / (peak/occupancy) / f
    per-block memory  = bytes/n_blocks / (bw/occupancy)
    block_time = max(per-block compute, per-block memory)
    latency = waves * occupancy * block_time ... simplified to:
    latency = max(flops/(f*peak), bytes/bw) / t_eff * quant(t_eff) + overhead

where ``quant`` is the wave-quantization factor (ceil effects) and compute
scales with frequency while HBM bandwidth does not.  This reproduces the
qualitative behaviours the paper's mechanisms exploit:

* Amdahl-style TPC scaling ``l = m/t + b`` (§4.5) with per-kernel m, b;
* frequency-insensitivity of memory-bound kernels (§4.6);
* an occupancy-derived upper bound on useful slices (the filtering
  heuristic's ground truth);
* tail/wave effects that make tiny kernels hard to model (the outliers the
  filtering heuristic exists for).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.types import DeviceSpec, KernelWork


@dataclass(frozen=True)
class WorkPhases:
    """A kernel reduced to simulator drain terms.

    ``c_work``  compute term in slice-seconds at f_max
    ``m_work``  memory term in slice-seconds
    ``overhead``fixed, allocation-independent launch/tail seconds
    ``max_useful_slices`` parallelism bound from the grid
    """

    c_work: float
    m_work: float
    overhead: float
    n_blocks: int
    max_useful_slices: int

    def divisible_time(self, t: int, f: float, occupancy: int) -> float:
        """Time for the divisible phase on ``t`` slices at rel. freq ``f``."""
        t_eff = max(1, min(t, self.max_useful_slices))
        quant = self.quantization(t_eff, occupancy)
        return max(self.c_work / f, self.m_work) / t_eff * quant

    def quantization(self, t_eff: int, occupancy: int) -> float:
        """Wave-quantization factor >= 1 (ceil of blocks into waves)."""
        per_wave = t_eff * occupancy
        waves = math.ceil(self.n_blocks / per_wave)
        ideal_waves = self.n_blocks / per_wave
        return waves / ideal_waves if ideal_waves > 0 else 1.0

    def latency(self, t: int, f: float, occupancy: int) -> float:
        return self.overhead + self.divisible_time(t, f, occupancy)


class CostModel:
    """Maps :class:`KernelWork` onto :class:`WorkPhases` for a device."""

    def __init__(self, device: DeviceSpec):
        self.device = device
        # phases() is on the per-dispatch hot path; KernelWork is an
        # unhashable dataclass but (flops, bytes, n_blocks) is its full
        # identity for this map.  WorkPhases is frozen, so sharing one
        # instance across dispatches is safe.  Op diversity per trace is
        # small and bounded, so the cache never grows past a few hundred
        # entries even on million-request runs.
        self._phase_cache: dict[tuple, WorkPhases] = {}

    def phases(self, work: KernelWork) -> WorkPhases:
        key = (work.flops, work.bytes, work.n_blocks)
        ph = self._phase_cache.get(key)
        if ph is not None:
            return ph
        d = self.device
        c_work = work.flops / d.peak_flops          # slice-seconds at f_max
        m_work = work.bytes / d.hbm_bw              # slice-seconds
        max_useful = max(1, math.ceil(work.n_blocks / d.occupancy))
        ph = WorkPhases(
            c_work=c_work,
            m_work=m_work,
            overhead=d.launch_overhead,
            n_blocks=max(1, work.n_blocks),
            max_useful_slices=max_useful,
        )
        self._phase_cache[key] = ph
        return ph

    def latency(self, work: KernelWork, t: int, f: float = 1.0) -> float:
        return self.phases(work).latency(t, f, self.device.occupancy)

    def energy(self, work: KernelWork, t: int, f: float = 1.0) -> float:
        """Energy attributable to this kernel alone (active-slice dynamic
        power over its duration) — used for per-kernel reporting; the
        simulator integrates true device power over time."""
        lat = self.latency(work, t, f)
        t_eff = min(t, self.phases(work).max_useful_slices)
        return lat * t_eff * self.device.p_dyn * f ** 3

    # Convenience ground-truth inspectors (benchmarks/tests only — the OS
    # never calls these).
    def is_compute_bound(self, work: KernelWork, f: float = 1.0) -> bool:
        return work.flops / (self.device.peak_flops * f) >= work.bytes / self.device.hbm_bw

    def arithmetic_intensity(self, work: KernelWork) -> float:
        return work.flops / max(1.0, work.bytes)
