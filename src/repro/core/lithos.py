"""LithOS facade: wire apps, quotas, policies, and the simulator together.

``evaluate(system, device, apps, ...)`` runs any of the nine systems
(lithos + 8 baselines) over the same workload mix and returns a SimResult —
the single entry point used by the benchmarks.
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import Optional

from repro.core import baselines
from repro.core.scheduler import LithOSConfig, LithOSScheduler
from repro.core.simulator import Policy, SimResult, Simulator
from repro.core.types import DeviceSpec, Priority, Quota
from repro.core.workloads import AppSpec

SYSTEMS = ("lithos", "mps", "mig", "limits", "timeslice", "priority",
           "reef", "tgs", "orion")


def quotas_from_apps(device: DeviceSpec,
                     apps: list[AppSpec]) -> dict[int, Quota]:
    """Derive per-client quotas: explicit quota_slices if given, else split
    the device proportionally among HP apps (BE gets 0 — it runs on steal)."""
    quotas: dict[int, Quota] = {}
    hp = [i for i, a in enumerate(apps) if a.priority == Priority.HIGH]
    explicit = sum(a.quota_slices for a in apps)
    left = device.n_slices - explicit
    for i, a in enumerate(apps):
        s = a.quota_slices
        if s == 0 and a.priority == Priority.HIGH and hp:
            s = max(1, left // len(hp))
        quotas[i] = Quota(s, a.priority)
    return quotas


def partitions_from_apps(device: DeviceSpec, apps: list[AppSpec],
                         gpc_granularity: int = 0) -> dict[int, int]:
    """MIG-style partitions: HP apps only, rounded to GPC boundaries."""
    quotas = quotas_from_apps(device, apps)
    parts = {}
    for cid, q in quotas.items():
        if apps[cid].priority != Priority.HIGH:
            continue
        s = q.slices
        if gpc_granularity > 1:
            s = max(gpc_granularity,
                    int(math.floor(s / gpc_granularity)) * gpc_granularity)
        parts[cid] = s
    # MIG cannot oversubscribe: shrink to fit
    total = sum(parts.values())
    while total > device.n_slices and parts:
        big = max(parts, key=parts.get)
        parts[big] -= gpc_granularity if gpc_granularity > 1 else 1
        total = sum(parts.values())
    return parts


def make_policy(system: str, device: DeviceSpec, apps: list[AppSpec], *,
                lithos_config: Optional[LithOSConfig] = None) -> Policy:
    if system == "lithos":
        return LithOSScheduler(device, quotas_from_apps(device, apps),
                               lithos_config or LithOSConfig())
    if system == "mig":
        return baselines.MIGPolicy(
            partitions_from_apps(device, apps,
                                 gpc_granularity=device.n_slices // 8))
    if system == "limits":
        return baselines.LimitsPolicy(partitions_from_apps(device, apps))
    return baselines.make_baseline(system)


def evaluate(system: str, device: DeviceSpec, apps: list[AppSpec], *,
             horizon: float = 30.0, seed: int = 0,
             lithos_config: Optional[LithOSConfig] = None) -> SimResult:
    policy = make_policy(system, device, apps, lithos_config=lithos_config)
    sim = Simulator(device, apps, policy, horizon=horizon, seed=seed)
    res = sim.run()
    res.policy = policy               # expose learned state to benchmarks
    return res


def run_alone(device: DeviceSpec, app: AppSpec, *, horizon: float = 30.0,
              seed: int = 0, system: str = "lithos",
              lithos_config: Optional[LithOSConfig] = None) -> SimResult:
    """Solo run of one app — the normalization baseline the paper uses for
    'ideal' latency and throughput-alone."""
    solo = replace(app, quota_slices=device.n_slices)
    return evaluate(system, device, [solo], horizon=horizon, seed=seed,
                    lithos_config=lithos_config)
