"""LithOS facade: wire apps, quotas, policies, and the simulator together.

``evaluate(system, device, apps, ...)`` runs any of the nine systems
(lithos + 8 baselines) over the same workload mix and returns a SimResult —
the single entry point used by the benchmarks.
"""
from __future__ import annotations

import math
import os
from dataclasses import replace
from typing import Optional

from repro.core import baselines
from repro.core.scheduler import LithOSConfig, LithOSScheduler
from repro.core.simulator import (Policy, SimResult, Simulator,
                                  make_simulator)
from repro.core.types import (ClusterConfig, ClusterSpec, DeviceSpec,
                              NodeConfig, NodeSpec, Priority, Quota)
from repro.core.workloads import AppSpec

SYSTEMS = ("lithos", "mps", "mig", "limits", "timeslice", "priority",
           "reef", "tgs", "orion")


def quotas_from_apps(device: DeviceSpec, apps: list[AppSpec],
                     cids: Optional[list[int]] = None) -> dict[int, Quota]:
    """Derive per-client quotas: explicit quota_slices if given, else split
    the device proportionally among HP apps (BE gets 0 — it runs on steal).

    Quotas are guarantees, so they must be *coverable*: the running total
    never exceeds ``device.n_slices``.  Explicit quotas are reserved first
    (clamped to the device, in list order), then derived HP shares are
    handed out from whatever remains — an explicit request that fits on its
    own is never degraded to cover a derived share, and an oversubscribed
    request degrades to what is left rather than silently promising
    capacity that does not exist.
    """
    if cids is None:
        cids = list(range(len(apps)))
    cap = device.n_slices
    hp = [a for a in apps if a.priority == Priority.HIGH]
    slices: dict[int, int] = {}
    total = 0
    for cid, a in zip(cids, apps):        # pass 1: explicit guarantees
        if a.quota_slices > 0:
            s = min(a.quota_slices, cap - total)
            slices[cid] = s
            total += s
    left = cap - total
    share = max(1, left // len(hp)) if (hp and left > 0) else 0
    for cid, a in zip(cids, apps):        # pass 2: derived HP shares
        if cid in slices:
            continue
        s = share if a.priority == Priority.HIGH else 0
        s = min(s, cap - total)
        slices[cid] = s
        total += s
    return {cid: Quota(slices[cid], a.priority)
            for cid, a in zip(cids, apps)}


def partitions_from_apps(device: DeviceSpec, apps: list[AppSpec],
                         gpc_granularity: int = 0,
                         cids: Optional[list[int]] = None) -> dict[int, int]:
    """MIG-style partitions: HP apps only, rounded to GPC boundaries."""
    if cids is None:
        cids = list(range(len(apps)))
    quotas = quotas_from_apps(device, apps, cids=cids)
    prio = {cid: a.priority for cid, a in zip(cids, apps)}
    parts = {}
    for cid, q in quotas.items():
        if prio[cid] != Priority.HIGH:
            continue
        s = q.slices
        if gpc_granularity > 1:
            s = max(gpc_granularity,
                    int(math.floor(s / gpc_granularity)) * gpc_granularity)
        parts[cid] = s
    # MIG cannot oversubscribe: shrink to fit
    total = sum(parts.values())
    while total > device.n_slices and parts:
        big = max(parts, key=parts.get)
        parts[big] -= gpc_granularity if gpc_granularity > 1 else 1
        total = sum(parts.values())
    return parts


def make_policy(system: str, device: DeviceSpec, apps: list[AppSpec], *,
                lithos_config: Optional[LithOSConfig] = None,
                cids: Optional[list[int]] = None) -> Policy:
    if system == "lithos":
        return LithOSScheduler(device, quotas_from_apps(device, apps,
                                                        cids=cids),
                               lithos_config or LithOSConfig())
    if system == "mig":
        return baselines.MIGPolicy(
            partitions_from_apps(device, apps,
                                 gpc_granularity=device.n_slices // 8,
                                 cids=cids))
    if system == "limits":
        return baselines.LimitsPolicy(
            partitions_from_apps(device, apps, cids=cids))
    return baselines.make_baseline(system)


def default_engine() -> str:
    """Simulator engine unless callers say otherwise: the scalar reference
    ("ref"), overridable via the REPRO_SIM_ENGINE environment variable
    (parity CI legs run the whole suite under "vec" this way)."""
    return os.environ.get("REPRO_SIM_ENGINE", "ref")


def evaluate(system: str, device, apps: list[AppSpec], *,
             horizon: float = 30.0, seed: int = 0,
             lithos_config: Optional[LithOSConfig] = None,
             router: str = "least_loaded",
             node_config: Optional[NodeConfig] = None,
             cluster_config: Optional[ClusterConfig] = None,
             placement: Optional[list] = None,
             engine: Optional[str] = None,
             collect_records: bool = True,
             faults=None):
    """Run one system over one workload mix.

    ``device`` may be a :class:`DeviceSpec` (single-device path, returns a
    :class:`SimResult`), a :class:`NodeSpec` (multi-device path: the node
    layer routes tenants across devices with ``router`` and returns a
    ``NodeResult``; a 1-device node reproduces the DeviceSpec path
    bit-for-bit), or a :class:`ClusterSpec` (the cluster tier routes
    tenants across nodes — ``router`` additionally accepts ``frag_aware``
    — and returns a ``ClusterResult``; a 1-node cluster reproduces the
    NodeSpec path bit-for-bit).  ``node_config`` tunes the node-level
    lending protocol (cross-device TPC stealing); ``cluster_config`` the
    cluster tier (cross-node stealing + power cap, with its own
    ``node_config`` field for the member nodes); ``placement`` pins tenants
    to devices (or (node, device) pairs), bypassing the routers.

    ``engine`` picks the simulator core ("ref" | "vec"; default from
    :func:`default_engine`) — results are bit-for-bit identical, "vec" is
    faster.  ``collect_records=False`` drops per-kernel records (throughput
    benchmarks on huge traces).

    ``faults`` is a :class:`~repro.core.types.FaultPlan`; its ``member``
    indices address flat device positions (0 for a bare DeviceSpec).
    ``faults=None`` is bit-for-bit the fault-free run."""
    if engine is None:
        engine = default_engine()
    if isinstance(device, ClusterSpec):
        from repro.core.cluster import evaluate_cluster
        if node_config is not None:
            raise ValueError("pass node_config for a ClusterSpec via "
                             "cluster_config.node_config")
        return evaluate_cluster(system, device, apps, horizon=horizon,
                                seed=seed, lithos_config=lithos_config,
                                router=router,
                                cluster_config=cluster_config,
                                placement=placement, engine=engine,
                                collect_records=collect_records,
                                faults=faults)
    if cluster_config is not None:
        raise ValueError("cluster_config requires a ClusterSpec")
    if isinstance(device, NodeSpec):
        from repro.core.node import evaluate_node
        return evaluate_node(system, device, apps, horizon=horizon,
                             seed=seed, lithos_config=lithos_config,
                             router=router, node_config=node_config,
                             placement=placement, engine=engine,
                             collect_records=collect_records,
                             faults=faults)
    if node_config is not None or placement is not None:
        raise ValueError("node_config/placement require a NodeSpec — a bare "
                         "DeviceSpec has no node layer to apply them to")
    policy = make_policy(system, device, apps, lithos_config=lithos_config)
    sim = make_simulator(device, apps, policy, engine=engine,
                         horizon=horizon, seed=seed,
                         collect_records=collect_records,
                         faults=(faults.events_for(0)
                                 if faults is not None else ()))
    res = sim.run()
    res.policy = policy               # expose learned state to benchmarks
    return res


def run_alone(device: DeviceSpec, app: AppSpec, *, horizon: float = 30.0,
              seed: int = 0, system: str = "lithos",
              lithos_config: Optional[LithOSConfig] = None,
              engine: Optional[str] = None) -> SimResult:
    """Solo run of one app — the normalization baseline the paper uses for
    'ideal' latency and throughput-alone."""
    solo = replace(app, quota_slices=device.n_slices)
    return evaluate(system, device, [solo], horizon=horizon, seed=seed,
                    lithos_config=lithos_config, engine=engine)
