"""§4.4 Kernel Atomizer.

Splits a kernel's grid into contiguous block-index ranges ("atoms") that are
independently schedulable.  The split count is ``predicted_duration /
atom_duration``; short kernels are left whole (the Prelude overhead is not
worth it) and kernels with huge grids get a larger effective atom_duration
(the paper's adaptive aggressiveness knob).

On TPU an atom is an offset-BlockSpec ``pallas_call`` over a sub-grid
(kernels/atom_matmul), so — unlike the paper's Prelude early-exit — there is
no dead-block traffic; the only cost is the per-launch overhead, which the
simulator charges per atom.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from repro.core.types import KernelTask


@dataclass
class AtomizerConfig:
    atom_duration: float = 1e-3        # target atom runtime (s)
    min_duration: float = 250e-6       # below this, never atomize
    max_atoms: int = 32
    min_blocks_per_atom: int = 8       # don't shred tiny grids
    # adaptive: grids larger than this get atom_duration scaled up so the
    # added launch traffic stays bounded (§4.4 "Performance Optimizations")
    large_grid_blocks: int = 4096
    large_grid_scale: float = 2.0


def atom_ranges(n_blocks: int, n_atoms: int) -> list[tuple[int, int]]:
    """Split [0, n_blocks) into ``n_atoms`` contiguous (start, len) ranges."""
    n_atoms = max(1, min(n_atoms, n_blocks))
    base, rem = divmod(n_blocks, n_atoms)
    out, start = [], 0
    for i in range(n_atoms):
        ln = base + (1 if i < rem else 0)
        out.append((start, ln))
        start += ln
    return out


class KernelAtomizer:
    def __init__(self, config: Optional[AtomizerConfig] = None):
        self.cfg = config or AtomizerConfig()
        self.atomized = 0
        self.passed_through = 0
        # Kernel-id stream for fresh atom ids — set to the owning
        # simulator's stream on policy attach (falls back to the module
        # global for standalone use in tests).
        self.kids = None

    def plan(self, task: KernelTask, predicted_latency: Optional[float],
             *, unseen_conservative: bool = False) -> int:
        """Number of atoms for this kernel (1 = pass through).

        ``unseen_conservative``: no latency estimate exists yet, but the
        kernel belongs to a best-effort tenant — split by grid size alone
        so a first encounter can never monopolize the device for a whole
        unknown kernel duration.  On TPU (grid-range atoms) this costs one
        launch per atom and nothing else — a beyond-paper improvement over
        the GPU Prelude's early-exit traffic (DESIGN.md §2)."""
        c = self.cfg
        if task.phase == "decode":
            # decode steps are memory-bound and already sub-quantum (one
            # token per sync) — atomizing them only adds launch overhead
            # on the latency-critical path.  Prefill atomizes like training.
            return 1
        if predicted_latency is None:
            if not unseen_conservative:
                return 1
            n = min(c.max_atoms, task.work.n_blocks // c.min_blocks_per_atom)
            return max(1, n)
        if predicted_latency < c.min_duration:
            return 1
        dur = c.atom_duration
        if task.work.n_blocks > c.large_grid_blocks:
            dur *= c.large_grid_scale
        n = int(predicted_latency / dur)
        n = min(n, c.max_atoms, task.work.n_blocks // c.min_blocks_per_atom)
        return max(1, n)

    def split(self, task: KernelTask, n_atoms: int) -> list[KernelTask]:
        """Materialize atoms: disjoint block ranges covering the full grid.

        Work terms scale with the block fraction; every block is executed
        exactly once across the returned atoms (property-tested).
        """
        if n_atoms <= 1:
            self.passed_through += 1
            return [task]
        ranges = atom_ranges(task.work.n_blocks, n_atoms)
        n = len(ranges)
        atoms = []
        for i, (start, ln) in enumerate(ranges):
            frac = ln / task.work.n_blocks
            atoms.append(replace(
                task,
                work=task.work.scaled(frac),
                kid=-1,                       # fresh id
                atom_of=(task.kid, i, n)))
        # fresh kids for atoms (dataclass replace keeps default factory out)
        from repro.core import types as _t
        kids = self.kids if self.kids is not None else _t._kernel_ids
        for a in atoms:
            a.kid = next(kids)
            a.work.n_blocks = max(1, a.work.n_blocks)
        self.atomized += 1
        return atoms
