"""Decode-roofline cost entries calibrated from the repo's Pallas kernels.

The sim's decode trace op (:func:`workloads.decode_attention_op`) is a
hand-written work model; the actual Pallas kernels under
``repro/kernels/decode_attention`` and ``repro/kernels/flash_attention``
have concrete tiling (block_k padding, row flattening, grouped heads).
This module derives :class:`KernelWork` terms from the *kernel geometry*
— same padding, same grid — and ties them to ``roofline/analysis.py``'s
three-term model, so:

* the predictor can be warm-started with roofline-derived decode
  latencies (``seed_decode_predictor``) instead of paying the
  conservative unseen-kernel default on the first serving iterations;
* a regression test can assert the sim's decode cost entries stay within
  tolerance of the kernel-derived roofline numbers — a kernel or
  analyzer change cannot silently skew decode timings
  (tests/test_llm_workloads.py).

Nothing here runs on the default scheduling path: seeding is opt-in
(benchmarks and the serving control plane call it), so legacy scenarios
are bit-for-bit unchanged.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.costmodel import CostModel
from repro.core.types import DeviceSpec, KernelWork
from repro.core.workloads import DSIZE, OpDesc
from repro.roofline.analysis import HW, RooflineTerms


def _pad_to(x: int, block: int) -> int:
    return ((x + block - 1) // block) * block


def decode_attention_work(B: int, S: int, n_q: int, n_kv: int, hd: int,
                          *, block_k: int = 512) -> KernelWork:
    """Work terms of ``kernels/decode_attention`` at the kernel's actual
    geometry: q [B,Hq,D] against caches [B,S,Hk,D], rows R=B*Hk flattened,
    G=Hq//Hk query heads per row, S padded to a block_k multiple."""
    bk = min(block_k, max(S, 16))
    Sp = _pad_to(S, bk)
    R = B * n_kv
    G = max(1, n_q // n_kv)
    # QK^T + AV over the padded window, per query head
    flops = 2.0 * 2.0 * R * G * Sp * hd
    # kf/vf stream the whole padded cache once; q and o are R*G*hd each
    byts = DSIZE * (R * Sp * hd * 2.0 + R * G * hd * 2.0)
    n_blocks = R * math.ceil(Sp / bk)
    return KernelWork(flops, byts, max(1, n_blocks))


def flash_attention_work(B: int, Sq: int, Skv: int, n_q: int, n_kv: int,
                         hd: int, *, block_q: int = 512,
                         block_k: int = 512) -> KernelWork:
    """Work terms of ``kernels/flash_attention`` at its actual tiling
    (both sequence dims padded to their block multiples; grid =
    B*Hq q-tiles)."""
    bq = min(block_q, max(Sq, 16))
    bk = min(block_k, max(Skv, 16))
    Sqp = _pad_to(Sq, bq)
    Skp = _pad_to(Skv, bk)
    flops = 2.0 * 2.0 * B * n_q * Sqp * Skp * hd
    byts = DSIZE * B * (Sqp * n_q * hd * 2.0 + Skp * n_kv * hd * 2.0)
    n_blocks = B * n_q * math.ceil(Sqp / bq)
    return KernelWork(flops, byts, max(1, n_blocks))


def device_hw(device: DeviceSpec) -> HW:
    """The roofline analyzer's HW record for a sim device (chips =
    slices; DeviceSpec rates are already per slice)."""
    return HW(f"sim-{device.n_slices}sl", device.peak_flops, device.hbm_bw,
              link_bw=device.hbm_bw)


def roofline_terms(work: KernelWork, device: DeviceSpec,
                   *, label: str = "decode") -> RooflineTerms:
    """Three-term roofline for one kernel on the device (no collective
    traffic: single-device kernels).  ``chips`` is the kernel's effective
    parallelism — decode grids are small, so the analyzer must see the
    same occupancy-capped slice count the cost model's parallelism bound
    enforces, not the whole device."""
    chips = min(device.n_slices,
                max(1, math.ceil(work.n_blocks / device.occupancy)))
    return RooflineTerms(
        arch=label, shape=label, mesh="device", chips=chips,
        hlo_flops=work.flops, hlo_bytes=work.bytes,
        collective_bytes_per_chip=0.0, model_flops=work.flops,
        hw=device_hw(device))


@dataclass(frozen=True)
class DecodeCostEntry:
    """One calibrated decode cost-table row."""

    batch: int
    kv_len: int
    work: KernelWork
    roofline_s: float           # analysis.py bound_time on the full device
    latency_s: float            # CostModel ground truth on the full device

    @property
    def rel_err(self) -> float:
        model = self.latency_s
        return abs(model - self.roofline_s) / max(self.roofline_s, 1e-12)


def decode_cost_table(cfg, device: DeviceSpec,
                      batches: tuple[int, ...] = (1, 2, 4, 8),
                      kv_lens: tuple[int, ...] = (512, 2048, 8192),
                      ) -> list[DecodeCostEntry]:
    """Kernel-geometry decode attention costed two ways: the roofline
    analyzer's bound_time and the sim CostModel's full-device latency.
    The regression test pins these against each other (launch overhead
    and wave quantization explain the residual)."""
    cost = CostModel(device)
    out = []
    for B in batches:
        for S in kv_lens:
            w = decode_attention_work(B, S, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim)
            terms = roofline_terms(w, device)
            lat = cost.latency(w, device.n_slices)
            out.append(DecodeCostEntry(B, S, w, terms.bound_time, lat))
    return out


def seed_decode_predictor(predictor, queue_id: int, trace: list[OpDesc],
                          device: DeviceSpec, slices: int) -> int:
    """Warm-start one launch queue's predictor nodes from the ground-truth
    cost model: one observation per (queue, ordinal) at ``slices`` and
    f_max, as if the kernels had already run once.  Returns the number of
    nodes seeded.  Opt-in — callers that want cold-start behavior simply
    don't call it."""
    cost = CostModel(device)
    for ordinal, op in enumerate(trace):
        lat = cost.latency(op.work(), slices)
        predictor.seed_node(queue_id, ordinal, slices, 1.0, lat)
    return len(trace)
