"""§4.3 TPC (core-slice) Scheduler — the LithOS policy.

Manages the device's slices like an OS manages CPU cores:

* **Quotas** (§4.2): each client is guaranteed its quota slices whenever it
  has work.  Unowned slices form a shared pool.
* **TPC Stealing**: slices owned by clients with *no pending work* are lent
  out; per-slice timers (predicted completion of the holding atom, from the
  §4.7 predictor) record when borrowed slices return.  The moment an owner
  has work queued, its slices stop being re-lent — in-flight atoms finish
  (bounded by atom_duration) and return.
* **Kernel Atomization** (§4.4): long kernels are split so every atom
  boundary is a reallocation/preemption point; head-of-line blocking is
  bounded by one atom, not one kernel.
* **Right-sizing** (§4.5) and **DVFS** (§4.6) hook in per-atom, inheriting
  the parent kernel's decisions.

Dispatch discipline: HP clients first; one atom in flight per queue (maximum
scheduling flexibility — the sync-queue backlog threshold of the paper, set
to its minimum); HP dispatches eagerly on whatever slices are free, BE only
when it can get a meaningful allocation.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.core.atomizer import AtomizerConfig, KernelAtomizer
from repro.core.dvfs import DVFSGovernor
from repro.core.predictor import LatencyPredictor
from repro.core.queues import Client
from repro.core.rightsizer import RightSizer
from repro.core.simulator import ExecKernel, Policy
from repro.core.slices import SliceMap, VecSliceMap
from repro.core.workloads import kv_floor_slices
from repro.core.types import (CompletionRecord, DeviceSpec, KernelTask,
                              Priority, Quota)

UNSEEN_DEFAULT_LATENCY = 2e-3     # conservative guess for never-seen kernels


@dataclass
class LithOSConfig:
    atomize: bool = True
    steal: bool = True
    rightsize: bool = False
    dvfs: bool = False
    occupancy_filter: bool = True   # §4.5 filtering heuristic (always-on in
                                    # LithOS; off = status-quo full alloc)
    slip: float = 1.1               # latency-slip parameter k (§4.5/4.6)
    probe_low: bool = True          # schedule the low-point calibration run
    # 1-slice probes are the paper's protocol; for latency-critical (HP)
    # kernels the low point is raised so one probe never exceeds this bound.
    # BE kernels always probe at 1 slice (they have no deadline).
    probe_latency_cap: float = 25e-3
    be_min_fraction: float = 0.05   # BE dispatches only if it can get this
    atomizer: AtomizerConfig = field(default_factory=AtomizerConfig)


@dataclass
class _QueueState:
    parent: Optional[KernelTask] = None
    atoms: deque = field(default_factory=deque)
    in_flight_kid: Optional[int] = None
    parent_slices: int = 0          # allocation decided for the kernel
    predicted: Optional[float] = None


class LithOSScheduler(Policy):
    name = "lithos"
    supports_migration = True

    def __init__(self, device: DeviceSpec, quotas: dict[int, Quota],
                 config: Optional[LithOSConfig] = None):
        self.device = device
        self.quotas = quotas
        self.cfg = config or LithOSConfig()
        self.predictor = LatencyPredictor(device.launch_overhead)
        self.atomizer = KernelAtomizer(self.cfg.atomizer)
        self.rightsizer = RightSizer(device.n_slices, device.occupancy,
                                     self.cfg.slip)
        self.governor = DVFSGovernor(device, self.cfg.slip)
        # slice state: ownership, holding, lending live in the SliceMap
        # subsystem (slices.py) — the scheduler is policy, not bookkeeping
        self.slices = SliceMap.from_quotas(device.n_slices, quotas)
        self.qstate: dict[int, _QueueState] = {}
        self.pred_log: list[tuple[float, float, int]] = []  # (pred, act, prio)
        self._grown: dict[int, int] = {}
        # clients with a planned kernel whose next atom is dispatchable
        # (atoms queued, nothing in flight) — the vec engine's step iterates
        # these plus the ready set instead of scanning every client
        self._disp: set[int] = set()
        # draining / paying migration cost.  Counted, not boolean: a stale
        # scheduled unhold (e.g. the migration-cost release of an earlier
        # move) must not cancel a newer drain-hold on the same client.
        self._held: dict[int, int] = {}
        # elastic re-own debt: evacuated owners whose guarantee could not
        # be fully re-granted at admit (destination pool busy) — fulfilled
        # from pool slices as they free up at completions
        self._pending_reown: dict[int, int] = {}

    def attach(self, sim):
        super().attach(sim)
        self.atomizer.kids = sim.kernel_ids
        if getattr(sim, "vec", False):
            # same layout/ordering contract, bitmask free-lists; built
            # fresh from the (unchanged) quotas
            self.slices = VecSliceMap.from_quotas(self.device.n_slices,
                                                  self.quotas)

    @property
    def stolen_slice_seconds(self) -> float:
        return self.slices.stolen_slice_seconds

    # -- helpers ------------------------------------------------------------------

    def _qs(self, cid: int) -> _QueueState:
        return self.qstate.setdefault(cid, _QueueState())

    def _has_work(self, c: Client) -> bool:
        """A workload is idle (its slices lendable) only between jobs —
        a client mid-request keeps its guarantee even while one of its
        kernels is executing (otherwise every kernel boundary leaks the
        quota to thieves and per-request latency compounds)."""
        qs = self._qs(c.cid)
        return (bool(qs.atoms) or c.peek() is not None or bool(c.pending)
                or c.current is not None or c.outstanding > 0)

    def _free_slices(self, for_cid: int, now: float) -> list[int]:
        """Slice ids this client may use right now.

        Lendability is priority-tiered (Fig 14's design point):
        * HP borrowers take any idle slice — HP apps steal unused
          resources from one another (an active owner's spare quota is
          still covered by its guarantee: it reclaims at atom boundaries).
        * BE borrowers only take slices of clients with NO in-flight job —
          otherwise repeated 1-atom borrows shave every kernel of an
          active HP request and the slowdown compounds through queueing.
        """
        lenders: list[int] = []
        if self.cfg.steal:
            hp_borrower = (self.quotas.get(for_cid, Quota(0)).priority
                           == Priority.HIGH)
            # owners with nothing idle contribute nothing to the stealable
            # set regardless of lendability, so only idle owners are probed
            # (free_for sorts the stealable union by slice id — lender
            # *membership*, not order, decides the outcome)
            for o in self.slices.idle_owners():
                if o == for_cid:
                    continue
                if hp_borrower or not self._has_work(self.sim.client_by_id[o]):
                    lenders.append(o)
        return self.slices.free_for(for_cid, lenders=lenders)

    def _n_own_idle(self, cid: int) -> int:
        return self.slices.n_own_idle(cid)

    # -- planning -------------------------------------------------------------------

    def _plan_kernel(self, c: Client, task: KernelTask, now: float):
        qs = self._qs(c.cid)
        # quota is a GUARANTEE (enforced via slice ownership + lendability),
        # not a cap: any client may use the whole device when others idle
        desired = self.device.n_slices
        # KV-cache memory floor: a serving tenant's live KV footprint pins
        # a minimum slice count (its memory share) — the right-sizer must
        # never shrink it below that, or live cache would be evicted.
        # Refreshed per kernel; relaxes as requests complete (kv_bytes
        # shrinks).  0 for tenants without a KV cache -> floor 1 -> no-op.
        floor = kv_floor_slices(c.spec.cfg, self.device,
                                getattr(c, "kv_bytes", 0.0))
        self.rightsizer.set_memory_floor(c.cid, floor)
        pred = self.predictor.predict(task, desired)
        # right-sizing (with the occupancy filter always applied)
        if self.cfg.rightsize:
            prio = self.quotas.get(c.cid, Quota(0)).priority
            cap = (self.cfg.probe_latency_cap
                   if prio == Priority.HIGH else 1.0)
            probe = (self.rightsizer.probe_allocation(
                task, desired, predicted_full=pred, probe_latency_cap=cap)
                if self.cfg.probe_low else None)
            if probe is not None:
                desired = probe        # calibration run (full, then 1 slice)
            else:
                desired = self.rightsizer.decide(task, desired)
        elif self.cfg.occupancy_filter:
            desired = min(desired, self.rightsizer.occupancy_bound(task))
        # the memory floor binds every shrink path (decide, probe low
        # point, occupancy filter alike)
        desired = max(desired, min(floor, self.device.n_slices))
        # atomization; unseen BE kernels split by grid size (an unknown
        # best-effort kernel must never monopolize stolen slices)
        prio = self.quotas.get(c.cid, Quota(0)).priority
        n_atoms = (self.atomizer.plan(
            task, pred,
            unseen_conservative=(prio == Priority.BEST_EFFORT))
            if self.cfg.atomize else 1)
        qs.parent = task
        qs.parent_slices = max(1, desired)
        qs.predicted = pred
        qs.atoms = deque(self.atomizer.split(task, n_atoms))

    # -- dispatch ---------------------------------------------------------------------

    def _sync_disp(self, cid: int, qs: _QueueState):
        if qs.atoms and qs.in_flight_kid is None:
            self._disp.add(cid)
        else:
            self._disp.discard(cid)

    def _dispatch_atom(self, c: Client, now: float,
                       qs: Optional[_QueueState] = None) -> bool:
        if qs is None:
            qs = self._qs(c.cid)
        if not qs.atoms or qs.in_flight_kid is not None:
            return False
        if self.slices.total_idle() == 0:
            return False        # free_for is empty for every client
        prio = self.quotas.get(c.cid, Quota(0)).priority
        if getattr(self.sim, "vec", False):
            # mask fast path: same chosen set and order as the reference
            # free_for[:want] (own idle asc, pool asc, stealable asc),
            # without materializing the full free-id list
            sm = self.slices
            steal = 0
            if self.cfg.steal:
                if prio == Priority.HIGH:
                    steal = (sm.idle_owned_union()
                             & ~sm.own_mask(c.cid))
                else:
                    cb = self.sim.client_by_id
                    for o in sm.idle_owners():
                        if o != c.cid and not self._has_work(cb[o]):
                            steal |= sm.idle_own_mask(o)
            picked, n_free = sm.take_free(c.cid, qs.parent_slices, steal)
            if not n_free:
                return False
            want = min(qs.parent_slices, n_free)
            if prio == Priority.BEST_EFFORT:
                floor = max(1, int(qs.parent_slices
                                   * self.cfg.be_min_fraction))
                if n_free < floor:
                    return False
            chosen = tuple(picked)
        else:
            free = self._free_slices(c.cid, now)
            if not free:
                return False
            want = min(qs.parent_slices, len(free))
            if prio == Priority.BEST_EFFORT:
                floor = max(1, int(qs.parent_slices
                                   * self.cfg.be_min_fraction))
                if len(free) < floor:
                    return False
            chosen = tuple(free[:want])
        atom = qs.atoms.popleft()
        n_atoms = atom.atom_of[2] if atom.atom_of else 1
        pred = self.predictor.predict(atom, want, self.governor.current_f,
                                      n_atoms=n_atoms)
        eta = pred if pred is not None else UNSEEN_DEFAULT_LATENCY
        stolen = self.slices.acquire(chosen, atom.kid, c.cid, now, eta=eta)
        ek = self.sim.start_kernel(c, atom, len(chosen), slice_set=chosen,
                                   stolen=stolen)
        qs.in_flight_kid = atom.kid
        ek._predicted = pred          # for §7.4 accuracy accounting
        return True

    # -- policy hooks --------------------------------------------------------------------

    def step(self, now: float):
        # DVFS: conservative — only below f_max when nothing in flight is unseen
        if self.cfg.dvfs:
            unseen = any(self.governor.unseen(ek.task)
                         for ek in self.sim.in_flight.values())
            if unseen:
                # full speed for the conservative-learning phase — but the
                # cluster power manager's cap still binds
                self.sim.set_frequency(self.governor._clamp(1.0))
                self.governor.current_f = self.sim.freq
            else:
                f = self.governor.maybe_switch(now)
                if f is not None:
                    self.sim.set_frequency(f)
        if getattr(self.sim, "vec", False):
            # candidate-set scan: clients that could plan (ready, not
            # draining) or dispatch a queued atom (_disp).  Everyone else
            # is a strict no-op in the reference loop below; the sort key
            # replicates its stable priority order (ties by client-list
            # position).
            sim = self.sim
            cands = [c for c in sim.ready_clients()
                     if c.cid not in self._held]
            # slices only free up via release (never during this loop), so
            # a zero-idle device stays zero-idle: every dispatch attempt is
            # a guaranteed no-op and _disp clients (planned, waiting on
            # capacity) can be skipped wholesale.  Ready clients still must
            # plan (pop + atomize) exactly as the reference loop does.
            idle = self.slices.total_idle() > 0
            if idle and self._disp:
                cb = sim.client_by_id
                for cid in self._disp:
                    c = cb.get(cid)
                    if c is not None:
                        cands.append(c)
            if cands:
                cands.sort(key=lambda c: (
                    -int(self.quotas.get(c.cid, Quota(0)).priority),
                    sim.client_pos(c.cid)))
                for c in cands:
                    qs = self._qs(c.cid)
                    if qs.parent is None:
                        task = c.peek()
                        if task is None:
                            continue
                        c.pop()
                        self._plan_kernel(c, task, now)
                    if idle:
                        if self._dispatch_atom(c, now, qs):
                            idle = self.slices.total_idle() > 0
                    self._sync_disp(c.cid, qs)
            self._grow_inflight(now)
            return
        order = sorted(
            self.sim.clients,
            key=lambda c: -int(self.quotas.get(c.cid, Quota(0)).priority))
        for c in order:
            qs = self._qs(c.cid)
            if qs.parent is None:
                # held clients drain at the current kernel boundary: the
                # in-flight kernel's atoms finish, nothing new is planned
                if c.cid in self._held:
                    continue
                task = c.peek()
                if task is not None:
                    c.pop()
                    self._plan_kernel(c, task, now)
            self._dispatch_atom(c, now)
            self._sync_disp(c.cid, qs)
        self._grow_inflight(now)

    def _grow_inflight(self, now: float):
        """Spread freed slices onto running atoms (remaining thread blocks
        flow onto freed cores — hardware-real growth, never shrink).
        Priority order; each atom grows at most to its planned allocation."""
        if not self.sim.in_flight or self.slices.total_idle() == 0:
            return              # nothing to spread / nothing to spread onto
        eks = sorted(self.sim.in_flight.values(),
                     key=lambda e: (-int(self.quotas.get(
                         e.client.cid, Quota(0)).priority), e.t_start))
        for ek in eks:
            qs = self._qs(ek.client.cid)
            want = qs.parent_slices
            if ek.slices >= want:
                continue
            free = self._free_slices(ek.client.cid, now)
            take = tuple(free[:want - ek.slices])
            if not take:
                continue
            self.slices.acquire(take, ek.task.kid, ek.client.cid, now)
            ek.slice_set = tuple(ek.slice_set) + take
            self._grown[ek.task.kid] = ek.slices + len(take)

    def allocations(self, now: float) -> dict[int, int]:
        out = {ek.task.kid: ek.slices for ek in self.sim.in_flight.values()}
        out.update(self._grown)
        self._grown = {}
        return out

    def alloc_changes(self, now: float) -> dict[int, int]:
        # only grown atoms ever differ from their current allocation
        # (interference_penalty is 0: the factor never moves)
        g = self._grown
        self._grown = {}
        return g

    def on_complete(self, ek: ExecKernel, rec: CompletionRecord):
        now = rec.t_end
        self._grown.pop(ek.task.kid, None)
        self.slices.release(ek.task.kid, now)
        if ek.stolen:
            self.slices.note_stolen_completion(rec.latency, rec.slices)
        self.predictor.observe(rec)
        self.rightsizer.observe(rec)
        self.governor.observe(rec)
        pred = getattr(ek, "_predicted", None)
        if pred is not None:
            prio = self.quotas.get(ek.client.cid, Quota(0)).priority
            self.pred_log.append((pred, rec.latency, int(prio)))
            self.predictor.record_outcome(pred, rec.latency)
        qs = self._qs(ek.client.cid)
        if qs.in_flight_kid == ek.task.kid:
            qs.in_flight_kid = None
        if not qs.atoms and qs.in_flight_kid is None:
            qs.parent = None
            ek.client.kernel_done(now)
        self._sync_disp(ek.client.cid, qs)
        if self._pending_reown:
            self._fulfill_reowns()

    # -- fault handling ------------------------------------------------------

    def on_fault(self, f, now: float):
        if f.kind == "slice_retired":
            self._retire_slice(f.slice_id, now)
            return
        if f.kind != "device_dead":
            return
        # device dead: REEF-reset every in-flight atom, put each planned
        # parent kernel back at its queue head — the tier above evacuates
        # intact queues, nothing is silently lost.  Atom kids are discarded
        # (the destination re-plans and re-atomizes with fresh ids).
        for kid in list(self.sim.in_flight):
            self._grown.pop(kid, None)
            self.slices.release(kid, now)
            self.sim.kill(kid)
        for cid, qs in self.qstate.items():
            if qs.parent is None:
                continue
            c = self.sim.client_by_id.get(cid)
            if c is not None:
                c.requeue(qs.parent)
            qs.parent = None
            qs.atoms.clear()
            qs.in_flight_kid = None
            qs.parent_slices = 0
            qs.predicted = None
            self._sync_disp(cid, qs)
        self._grown = {}

    def _retire_slice(self, sid: int, now: float):
        """ECC-style loss of one slice: out of the free-lists forever (lazily
        if held — blocks are non-preemptible), and the owner's quota shrinks
        by one so the guarantee tracks the hardware that still exists.  The
        KV memory floor is unaffected: it binds the right-sizer's *shrink*
        paths, and dispatch clamps to whatever capacity survives."""
        owner = self.slices.owner[sid]
        self.slices.retire(sid)
        if owner is not None:
            q = self.quotas.get(owner)
            if q is not None and q.slices > 0:
                self.quotas[owner] = Quota(q.slices - 1, q.priority)

    def _fair_hp_share(self) -> int:
        """Per-HP-owner fair share of the surviving capacity — the quota
        re-derivation target when an evacuee's guarantee must squeeze into
        an already-partitioned destination."""
        alive = self.device.n_slices - len(self.slices.retired)
        n_hp = sum(1 for q in self.quotas.values()
                   if q.priority == Priority.HIGH)
        return alive // max(1, n_hp)

    def _grant_reown(self, cid: int, want: int) -> int:
        """Re-grant up to ``want`` slices of ownership to an evacuee: idle
        pool slices first (free capacity), then — up to the fair HP share —
        idle slices reclaimed from HP owners holding more than that share.
        Grows ``cid``'s quota by what was actually granted."""
        granted = 0
        for sid in self.slices.idle_pool()[:want]:
            self.slices.assign_owner(sid, cid)
            granted += 1
        if granted < want:
            fair = self._fair_hp_share()
            have = self.quotas.get(cid, Quota(0)).slices + granted
            room = min(want - granted, max(0, fair - have))
            if room:
                granted += self._reclaim_from_rich(cid, room, fair)
        if granted:
            q = self.quotas.get(cid, Quota(0))
            self.quotas[cid] = Quota(q.slices + granted, q.priority)
        return granted

    def _reclaim_from_rich(self, cid: int, want: int, fair: int) -> int:
        """Transfer idle slices from HP owners above the fair share to the
        re-owning evacuee (held slices transfer later, as they free)."""
        got = 0
        for o in sorted(self.quotas):
            if o == cid or got >= want:
                continue
            q = self.quotas[o]
            if q.priority != Priority.HIGH or q.slices <= fair:
                continue
            take = min(q.slices - fair, want - got)
            ids = self.slices.idle_owned(o)[:take]
            for sid in ids:
                self.slices.assign_owner(sid, cid)
            if ids:
                self.quotas[o] = Quota(q.slices - len(ids), q.priority)
                got += len(ids)
        return got

    def _fulfill_reowns(self):
        for cid in sorted(self._pending_reown):
            want = self._pending_reown[cid]
            got = self._grant_reown(cid, want)
            if got >= want:
                del self._pending_reown[cid]
            else:
                self._pending_reown[cid] = want - got

    # -- cross-device migration protocol (node-level lending, §4.3 scaled
    # -- out: the NodeCoordinator drives hold -> drain -> export / import) --

    def hold_client(self, cid: int):
        self._held[cid] = self._held.get(cid, 0) + 1

    def release_hold(self, cid: int):
        n = self._held.get(cid, 0) - 1      # stale release: no-op
        if n > 0:
            self._held[cid] = n
        else:
            self._held.pop(cid, None)

    def client_drained(self, cid: int) -> bool:
        c = self.sim.client_by_id.get(cid)
        qs = self.qstate.get(cid)
        return (c is not None and c.outstanding == 0
                and (qs is None or (qs.parent is None and not qs.atoms
                                    and qs.in_flight_kid is None)))

    def export_client_state(self, cid: int) -> dict:
        """Drop a drained client from this device's control plane and hand
        its learned predictor state to the target (the queue keeps its
        node-global id, so (queue, ordinal) keys stay valid there)."""
        assert self.client_drained(cid), "export requires a drained client"
        self.qstate.pop(cid, None)
        self._held.pop(cid, None)       # all holds die with the residency
        self._disp.discard(cid)
        quota = self.quotas.pop(cid, Quota(0))
        # elastic re-own (HP migration): a drained owner's slices are all
        # idle — return them to this device's pool and record how many, so
        # the destination re-derives an equivalent grant from its own pool
        reown = self._pending_reown.pop(cid, 0)
        for sid in self.slices.idle_owned(cid):
            self.slices.disown(sid)
            reown += 1
        assert self.slices.owned_by(cid) == 0, \
            "cannot export an owner while borrowers hold its slices"
        keys = [k for k in self.predictor.nodes if k[0] == cid]
        nodes = {k: self.predictor.nodes.pop(k) for k in keys}
        return {"quota": quota, "predictor_nodes": nodes, "reown": reown}

    def import_client_state(self, cid: int, priority, state: dict):
        """Admit a migrated client: its quota re-derived against this
        device's idle pool (elastic re-own — an HP tenant re-acquires up to
        its exported ownership, a BE tenant stays quota-less on stolen
        capacity), plus the source predictor's observations so the first
        kernels on the new device dispatch with warm latency estimates."""
        quota = state.get("quota") or Quota(0, priority)
        reown = int(state.get("reown", 0) or 0)
        if reown:
            self.quotas[cid] = Quota(0, quota.priority)
            granted = self._grant_reown(cid, reown)
            if granted < reown:
                # outstanding debt is capped at the fair share: the
                # evacuee is entitled to free capacity without limit but
                # squeezes other guarantees only down to parity
                debt = min(reown - granted,
                           max(0, self._fair_hp_share() - granted))
                if debt:
                    self._pending_reown[cid] = debt
        else:
            self.quotas[cid] = quota
        for k, v in state.get("predictor_nodes", {}).items():
            self.predictor.nodes[k] = v

