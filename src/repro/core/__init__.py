"""LithOS control plane: the paper's contribution, as a composable library.

Layers (DESIGN.md §2-3):
  execution plane — real JAX models/kernels (repro.models, repro.kernels)
  control plane   — scheduler/atomizer/rightsizer/DVFS/predictor (here)
  timing plane    — calibrated discrete-event simulator (simulator.py)
"""
from repro.core.types import (CompletionRecord, DeviceSpec, KernelTask,
                              KernelWork, Priority, Quota)
from repro.core.costmodel import CostModel
from repro.core.lithos import SYSTEMS, evaluate, run_alone
