"""LithOS control plane: the paper's contribution, as a composable library.

Layers (DESIGN.md §2-3, §5):
  execution plane — real JAX models/kernels (repro.models, repro.kernels)
  control plane   — scheduler/atomizer/rightsizer/DVFS/predictor (here),
                    backed by the SliceMap resource subsystem (slices.py)
  timing plane    — calibrated discrete-event simulator (simulator.py)
  node layer      — multi-device placement/routing (node.py) over NodeSpec
"""
from repro.core.types import (CompletionRecord, DeviceSpec, KernelTask,
                              KernelWork, NodeSpec, Priority, Quota)
from repro.core.costmodel import CostModel
from repro.core.lithos import SYSTEMS, evaluate, run_alone
from repro.core.slices import SliceMap
