"""Baseline scheduling policies over the same simulator (§6 Baselines).

NVIDIA-native mechanisms — TimeSlice, MPS, stream Priority, MIG — plus the
SotA research systems the paper compares against: TGS (transparent adaptive
rate control), REEF (reset-based preemption), Orion (interference-aware
kernel gating, with its offline-profiling advantage granted as oracle access
to kernel boundedness).

TPU-adaptation note (DESIGN.md §2): MPS's intra-SM stacking has no TPU
analogue; here "MPS" means unrestricted concurrent execution with
processor-sharing of core-slices — the closest core-granular equivalent.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.costmodel import CostModel
from repro.core.queues import Client
from repro.core.simulator import ExecKernel, Policy
from repro.core.slices import SliceMap, VecSliceMap
from repro.core.types import CompletionRecord, Priority


def equal_share(items: list[tuple[int, int]], capacity: int) -> dict[int, int]:
    """Waterfill ``capacity`` slices over (kid, cap) items, equal shares with
    redistribution of unused headroom."""
    alloc = {kid: 0 for kid, _ in items}
    caps = dict(items)
    active = [kid for kid, _ in items]
    left = capacity
    while left > 0 and active:
        share = max(1, left // len(active))
        progressed = False
        for kid in list(active):
            give = min(share, caps[kid] - alloc[kid], left)
            if give > 0:
                alloc[kid] += give
                left -= give
                progressed = True
            if alloc[kid] >= caps[kid]:
                active.remove(kid)
            if left <= 0:
                break
        if not progressed:
            break
    return alloc


class FIFOPolicyBase(Policy):
    """Shared plumbing: strict per-queue FIFO, one kernel in flight per
    client; subclasses decide admission + allocation.

    Block semantics: a dispatched kernel grabs ``min(max_useful, free)``
    slices and holds them to completion; freed slices are re-granted in
    dispatch order (priority first), so a long low-priority kernel blocks
    later arrivals — the head-of-line effect LithOS's atomization removes.
    """

    # whether admit() is side-effect free.  The vec engine's candidate loop
    # skips admission probes when no slices are free ONLY for pure policies;
    # impure admission (TGS consumes a token per probe) must still be called
    # for every ready candidate, exactly like the reference loop does.
    pure_admit = True

    def admit(self, c: Client, now: float) -> bool:
        return True

    def _order(self):
        return sorted(self.sim.clients, key=lambda c: -int(c.spec.priority))

    def _order_vec(self):
        # ready clients only, in the same stable priority order as
        # ``sorted(clients, key=-priority)`` restricted to them.  Clients
        # without a dispatchable kernel are strict no-ops in the reference
        # loop (peek() is None -> continue), so skipping them is exact.
        return self.sim.ready_by_priority()

    def step(self, now: float):
        sim = self.sim
        if getattr(sim, "vec", False):
            if self.pure_admit and sim.free_slices() <= 0:
                return             # no dispatch possible, no probe effects
            for c in self._order_vec():
                task = c.peek()
                if task is None or not self.admit(c, now):
                    continue
                free = sim.free_slices()
                if free <= 0:
                    if self.pure_admit:
                        break      # remaining iterations are no-ops
                    continue       # HoL: wait for running blocks
                c.pop()
                cap = sim.cost.phases(task.work).max_useful_slices
                sim.start_kernel(c, task, min(cap, free))
            return
        for c in self._order():
            task = c.peek()
            if task is None or not self.admit(c, now):
                continue
            free = self.sim.free_slices()
            if free <= 0:
                continue               # HoL: wait for running blocks
            c.pop()
            cap = self.sim.cost.phases(task.work).max_useful_slices
            self.sim.start_kernel(c, task, min(cap, free))

    def on_complete(self, ek: ExecKernel, rec: CompletionRecord):
        ek.client.kernel_done(rec.t_end)

    # grow-on-free: spread free slices over in-flight kernels, HP first
    def allocations(self, now: float) -> dict[int, int]:
        out = {ek.task.kid: ek.slices for ek in self.sim.in_flight.values()}
        free = self.sim.free_slices()
        eks = sorted(self.sim.in_flight.values(),
                     key=lambda e: (-int(e.client.spec.priority), e.t_start))
        for ek in eks:
            if free <= 0:
                break
            grow = min(ek.phases.max_useful_slices - ek.slices, free)
            if grow > 0:
                out[ek.task.kid] = ek.slices + grow
                free -= grow
        return out

    def alloc_changes(self, now: float) -> dict[int, int]:
        # grown kernels only; everything else keeps its allocation, and the
        # engine re-checks the interference factor itself
        free = self.sim.free_slices()
        if free <= 0:
            return {}
        out: dict[int, int] = {}
        eks = sorted(self.sim.in_flight.values(),
                     key=lambda e: (-int(e.client.spec.priority), e.t_start))
        for ek in eks:
            if free <= 0:
                break
            grow = min(ek.phases.max_useful_slices - ek.slices, free)
            if grow > 0:
                out[ek.task.kid] = ek.slices + grow
                free -= grow
        return out


class MPSPolicy(FIFOPolicyBase):
    """Unrestricted concurrency with no prioritization (MPS has none):
    freed slices spread equally over in-flight kernels' headroom.
    Co-resident tenants pay cross-tenant interference (§2.2)."""

    name = "mps"
    interference_penalty = 0.18

    def _order(self):
        # FIFO, not priority: MPS is oblivious to tenant priorities
        return self.sim.clients

    def _order_vec(self):
        return self.sim.ready_clients()     # client-list order, ready only

    def allocations(self, now: float) -> dict[int, int]:
        out = {ek.task.kid: ek.slices for ek in self.sim.in_flight.values()}
        free = self.sim.free_slices()
        if free <= 0:
            return out
        headroom = [(ek.task.kid, ek.phases.max_useful_slices - ek.slices)
                    for ek in self.sim.in_flight.values()
                    if ek.phases.max_useful_slices > ek.slices]
        extra = equal_share(headroom, free)
        for kid, g in extra.items():
            out[kid] += g
        return out

    def alloc_changes(self, now: float) -> dict[int, int]:
        free = self.sim.free_slices()
        if free <= 0:
            return {}
        inf = self.sim.in_flight
        headroom = [(ek.task.kid, ek.phases.max_useful_slices - ek.slices)
                    for ek in inf.values()
                    if ek.phases.max_useful_slices > ek.slices]
        extra = equal_share(headroom, free)
        return {kid: inf[kid].slices + g for kid, g in extra.items() if g > 0}


class MIGPolicy(FIFOPolicyBase):
    """Static spatial partitions; clients without a partition never run and
    idle partition capacity cannot be donated (the MIG waste the paper
    quantifies).

    Runs on the same :class:`SliceMap` subsystem as LithOS but only ever
    acquires from its own partition — stealing is structurally impossible,
    so the subsystem's conservation checks double as a no-donation proof.
    """

    name = "mig"

    def __init__(self, partitions: dict[int, int]):
        self.partitions = partitions
        self.slices: SliceMap = None

    def attach(self, sim):
        super().attach(sim)
        cls = (VecSliceMap if getattr(sim, "vec", False) else SliceMap)
        self.slices = cls.from_partitions(sim.device.n_slices,
                                          self.partitions)

    def admit(self, c: Client, now: float) -> bool:
        return self.partitions.get(c.cid, 0) > 0

    def step(self, now: float):
        sim = self.sim
        vec = getattr(sim, "vec", False)
        if vec and self.slices.n_owned_idle_total() == 0:
            return                  # every partition busy: all no-ops
        for c in (self._order_vec() if vec else self._order()):
            task = c.peek()
            if task is None or not self.admit(c, now):
                continue
            own = self.slices.idle_owned(c.cid)
            if not own:
                continue
            cap = self.sim.cost.phases(task.work).max_useful_slices
            c.pop()
            chosen = tuple(own[:cap])
            self.slices.acquire(chosen, task.kid, c.cid, now)
            self.sim.start_kernel(c, task, len(chosen), slice_set=chosen)

    def on_complete(self, ek: ExecKernel, rec: CompletionRecord):
        self.slices.release(ek.task.kid, rec.t_end)
        super().on_complete(ek, rec)

    def allocations(self, now: float) -> dict[int, int]:
        return {ek.task.kid: ek.slices
                for ek in self.sim.in_flight.values()}

    def alloc_changes(self, now: float) -> dict[int, int]:
        return {}                   # partitions are static: never grows


class LimitsPolicy(MIGPolicy):
    """Thread-percentage limits (MPS active-thread quotas): like MIG but
    partitions are arbitrary slice counts (no GPC rounding)."""

    name = "limits"


class TimeSlicePolicy(FIFOPolicyBase):
    """Round-robin whole-device quanta (NVIDIA default time slicing).
    Out-of-turn kernels are context-switched out (allocation 0, progress
    frozen) — the one hardware mechanism that may shrink allocations."""

    name = "timeslice"
    allow_shrink = True

    def __init__(self, quantum: float = 5e-3):
        self.quantum = quantum
        self.tick_interval = quantum
        self.turn = 0
        self._applied_turn: Optional[int] = None   # last turn pushed to engine

    def _turn_cid(self) -> int:
        # ``turn`` indexes the client list; compare by cid (client ids are
        # node-global and need not be 0..n-1)
        clients = self.sim.clients
        return clients[self.turn % len(clients)].cid if clients else -1

    def step(self, now: float):
        # dispatch without a global free check: frozen kernels hold nothing
        turn_cid = self._turn_cid()
        vec = getattr(self.sim, "vec", False)
        for c in (self._order_vec() if vec else self._order()):
            task = c.peek()
            if task is None:
                continue
            c.pop()
            cap = self.sim.cost.phases(task.work).max_useful_slices
            s = min(cap, self.sim.device.n_slices) if c.cid == turn_cid else 0
            self.sim.start_kernel(c, task, s)

    def on_tick(self, now: float):
        n = len(self.sim.clients)
        for _ in range(n):
            self.turn = (self.turn + 1) % n
            c = self.sim.clients[self.turn]
            if c.peek() is not None or any(
                    ek.client.cid == c.cid
                    for ek in self.sim.in_flight.values()):
                break

    def allocations(self, now: float) -> dict[int, int]:
        turn_cid = self._turn_cid()
        return {ek.task.kid:
                (min(self.sim.device.n_slices, ek.phases.max_useful_slices)
                 if ek.client.cid == turn_cid else 0)
                for ek in self.sim.in_flight.values()}

    def alloc_changes(self, now: float) -> dict[int, int]:
        # targets depend only on whose turn it is; dispatches already start
        # at their target, so between turn rotations nothing can differ
        tc = self._turn_cid()
        if tc == self._applied_turn:
            return {}
        self._applied_turn = tc
        return self.allocations(now)


class PriorityPolicy(FIFOPolicyBase):
    """CUDA stream priority: HP kernels take slices first, BE gets leftovers
    (no gating — BE long kernels still launch and block resources).
    Co-residency pays MPS-style interference."""

    name = "priority"
    interference_penalty = 0.18


class REEFPolicy(FIFOPolicyBase):
    """REEF as re-implemented by the paper (§6): BE kernels are not launched
    while *any* HP app is active.  Launch gating only — an already-running
    BE kernel is not preempted, so HP arrivals can still wait out one whole
    BE kernel (the HoL effect Fig 20 quantifies).  Set ``reset=True`` for
    the original paper's reset-based preemption (kills BE, losing progress).
    """

    name = "reef"

    def __init__(self, reset: bool = False):
        self.reset = reset
        self._hp_memo: Optional[bool] = None

    def _hp_active(self) -> bool:
        # memoized for the duration of one step() call: within it an HP
        # client can only pop (peek None but outstanding > 0 — still
        # active) and the reset branch kills BE kernels only, so the value
        # cannot flip mid-step
        if self._hp_memo is None:
            self._hp_memo = any(
                c.spec.priority == Priority.HIGH and (
                    c.peek() is not None or c.outstanding > 0 or c.pending)
                for c in self.sim.clients)
        return self._hp_memo

    def admit(self, c: Client, now: float) -> bool:
        if c.spec.priority == Priority.HIGH:
            return True
        return not self._hp_active()

    def step(self, now: float):
        self._hp_memo = None
        if self.reset and self._hp_active():
            for ek in list(self.sim.in_flight.values()):
                if ek.client.spec.priority == Priority.BEST_EFFORT:
                    task = self.sim.kill(ek.task.kid)
                    if task is not None:
                        ek.client.requeue(task)
        super().step(now)


class TGSPolicy(FIFOPolicyBase):
    """Transparent GPU sharing: adaptive rate control on BE kernel launches.

    A token rate for BE work adapts to HP progress: when HP requests see
    queueing, the BE rate collapses; when HP is idle it ramps up.  The
    paper's critique — the controller assumes steady arrivals and reacts
    slowly to bursts — emerges from the ramp dynamics."""

    name = "tgs"
    tick_interval = 10e-3
    interference_penalty = 0.18          # co-runs on MPS-style stacking
    pure_admit = False                   # admit() consumes a token

    def __init__(self, ramp: float = 1.15, collapse: float = 0.25):
        self.rate = 0.5                  # BE duty fraction [0,1]
        self.tokens = 0.0
        self.ramp = ramp
        self.collapse = collapse
        self._last_hp_wait = 0.0

    def on_tick(self, now: float):
        hp_waiting = any(
            c.spec.priority == Priority.HIGH and
            (c.peek() is not None or c.pending)
            for c in self.sim.clients)
        if hp_waiting:
            self.rate = max(0.02, self.rate * self.collapse)
        else:
            self.rate = min(1.0, self.rate * self.ramp)
        self.tokens = min(2.0, self.tokens + self.rate)

    def admit(self, c: Client, now: float) -> bool:
        if c.spec.priority == Priority.HIGH:
            return True
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


class OrionPolicy(FIFOPolicyBase):
    """Interference-aware gating: a BE kernel launches only if it does not
    contend with ANY in-flight HP kernel.  Contention = same roofline
    boundedness class; Orion knows each kernel's class from offline
    profiling, granted here as oracle access to the cost model."""

    name = "orion"

    def _bound_class(self, ek_or_task) -> bool:
        task = ek_or_task.task if isinstance(ek_or_task, ExecKernel) else ek_or_task
        # sim.cost is the same device's model; is_compute_bound is pure
        return self.sim.cost.is_compute_bound(task.work)

    def admit(self, c: Client, now: float) -> bool:
        if c.spec.priority == Priority.HIGH:
            return True
        hp_classes = {self._bound_class(ek)
                      for ek in self.sim.in_flight.values()
                      if ek.client.spec.priority == Priority.HIGH}
        hp_queued = any(cc.spec.priority == Priority.HIGH and
                        (cc.peek() is not None or cc.pending)
                        for cc in self.sim.clients)
        if hp_queued:
            return False
        task = c.peek()
        return self._bound_class(task) not in hp_classes


def make_baseline(name: str, **kw) -> Policy:
    table = {"mps": MPSPolicy, "mig": MIGPolicy, "limits": LimitsPolicy,
             "timeslice": TimeSlicePolicy, "priority": PriorityPolicy,
             "reef": REEFPolicy, "tgs": TGSPolicy, "orion": OrionPolicy}
    return table[name](**kw)
