"""Cluster tier: the node machinery instantiated one level up.

A :class:`~repro.core.types.ClusterSpec` is N nodes behind one placement and
power plane.  Everything the node tier built — pressure sampling, placement
routing, the drain/export/admit migration pipeline, ledger conservation —
is reused verbatim from :mod:`repro.core.hierarchy`; the only new code here
is the :class:`NodeMember` adapter (a whole
:class:`~repro.core.node.NodeCoordinator` as one member), the
fragmentation-aware placement policy, and the cluster power manager.

Three cluster-level mechanisms compose:

* **Placement** (:func:`place_cluster`) — the four node routers generalize
  to nodes (capacities = node slice totals), plus ``frag_aware``:
  best-fit-decreasing of HP guarantees onto the flat device list, which
  minimizes the FRAG-style stranded-free-capacity score
  (:func:`~repro.core.hierarchy.fragmentation`) and consolidates load so
  whole devices stay idle (the power win feeds the cap below).
* **Cross-node stealing** — the same lending protocol as PR 2, at node
  granularity: node pressure is the aggregate of its devices', and a
  saturated node's best-effort tenant migrates to an idle node through the
  exact export/import path devices use, charged a (larger)
  ``migration_cost``.  Intra-node stealing keeps running underneath; the
  coordinator's frozen set keeps the two tiers off the same client.
* **Power capping** (:class:`ClusterPowerManager`) — at every cluster
  epoch, per-device DVFS f-states are planned against
  ``ClusterConfig.power_cap`` with
  :func:`~repro.core.dvfs.plan_power_budget` (best-effort-only devices
  throttle first; HP devices keep ``power_hp_floor``), applied through the
  simulator's f-switch machinery and pinned via the governor's ``f_cap``.

A 1-node cluster with no cluster-level mechanisms is bit-for-bit
``evaluate_node`` — the same parity contract the node tier keeps with the
bare device, one level up (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Optional

from repro.core.dvfs import plan_power_budget
from repro.core.hierarchy import (ROUTERS, HierarchyCoordinator, Member,
                                  Pressure, fragmentation, route)
from repro.core.node import (NodeCoordinator, NodeResult, SimResult,
                             build_node, demand_estimate, place)
from repro.core.slices import MemberLedger
from repro.core.types import ClusterConfig, ClusterSpec, FaultPlan, Priority
from repro.core.workloads import AppSpec

CLUSTER_ROUTERS = ROUTERS + ("frag_aware",)


class NodeMember(Member):
    """One node as a hierarchy member: a whole :class:`NodeCoordinator`.

    The recursion that makes the hierarchy level-agnostic — a node's
    coordinator already exposes the event-stream interface
    (``start``/``peek_time``/``step_event``) its own device members do, so
    adapting it is aggregation plus routing protocol calls to the device
    currently hosting the client (the node ledger knows)."""

    def __init__(self, coord: NodeCoordinator):
        self.coord = coord
        self.capacity = coord.node.total_slices

    # -- event stream -------------------------------------------------------

    @property
    def horizon(self) -> float:
        return self.coord.sims[0].horizon

    def start(self):
        self.coord.start()

    def peek_time(self):
        return self.coord.peek_time()

    def step_event(self) -> bool:
        return self.coord.step_event()

    @property
    def done(self) -> bool:
        return self.coord.done

    def invalidate_peeks(self):
        self.coord.invalidate_peeks()

    # -- fault domain --------------------------------------------------------

    def failed(self) -> bool:
        """A node is dead only when every device below it is."""
        ms = self.coord.members
        return bool(ms) and all(m.failed() for m in ms)

    def has_faults(self) -> bool:
        return any(m.has_faults() for m in self.coord.members)

    def can_host(self, client) -> bool:
        return any(not m.failed() and m.can_host(client)
                   for m in self.coord.members)

    # -- pressure / placement ----------------------------------------------

    def pressure(self) -> Pressure:
        hp_depth = active = free = decode_depth = 0
        for m in self.coord.members:
            p = m.pressure()
            hp_depth += p.hp_depth
            active += p.active
            decode_depth += p.decode_depth
            free += m._free()
        return Pressure(hp_depth, free / self.capacity, active, decode_depth)

    def free_snapshot(self) -> list[int]:
        return [f for m in self.coord.members for f in m.free_snapshot()]

    # -- migration protocol -------------------------------------------------

    def _host(self, cid: int):
        """Device member currently hosting ``cid`` (per the node ledger)."""
        return self.coord.members[self.coord.ledger.current[cid]]

    def supports_migration(self) -> bool:
        return all(m.supports_migration() for m in self.coord.members)

    def migration_candidates(self) -> list[int]:
        """Union of the devices' candidates, minus any client the node's
        own coordinator is mid-drain on."""
        busy = ({self.coord._pending.cid}
                if self.coord._pending is not None else set())
        out = set()
        for m in self.coord.members:
            out.update(m.migration_candidates())
        return sorted(out - busy - self.coord.frozen)

    def begin_drain(self, cid: int):
        self.coord.frozen.add(cid)      # keep the node tier off this client
        self._host(cid).begin_drain(cid)

    def abort_drain(self, cid: int):
        self._host(cid).abort_drain(cid)
        self.coord.frozen.discard(cid)

    def drain_dead(self, cid: int) -> bool:
        return self._host(cid).drain_dead(cid)

    def drained(self, cid: int) -> bool:
        return self._host(cid).drained(cid)

    def clock(self, cid: int) -> float:
        return self._host(cid).clock(cid)

    def export_client(self, cid: int):
        host = self._host(cid)
        now = host.clock(cid)
        out = host.export_client(cid)
        self.coord.ledger.drop(cid, now)    # left this node's scope
        self.coord.frozen.discard(cid)
        return out

    def admit_client(self, client, priority, state, *, after: float,
                     release_at: float):
        ms = self.coord.members
        # dead devices never receive admits; among the survivors, prefer
        # one whose capacity can hold the client's KV floor (can_host),
        # then the most free (capacity-normalized), ties to the lowest id
        live = [i for i in range(len(ms)) if not ms[i].failed()]
        assert live, "admit_client on a fully dead node"
        fit = [i for i in live if ms[i].can_host(client)]
        cands = fit or live
        d = min(cands, key=lambda i: (-ms[i]._free() / ms[i].capacity, i))
        ms[d].admit_client(client, priority, state, after=after,
                           release_at=release_at)
        self.coord.ledger.adopt(client.cid, d)

    # -- invariants ---------------------------------------------------------

    def hosted_cids(self) -> list[int]:
        return [cid for m in self.coord.members for cid in m.hosted_cids()]

    def check(self):
        return self.coord.check()


# ---------------------------------------------------------------------------
# Cluster placement
# ---------------------------------------------------------------------------

def _slice_requests(cluster: ClusterSpec, apps: list[AppSpec]) -> list[int]:
    """Placement-time slice request per app: explicit quotas exact, derived
    HP shares estimated against the modal device, BE = 0 (stolen capacity).
    These are the 'tenant demand distribution' the fragmentation metric
    scores free-lists against."""
    caps = [d.n_slices for node in cluster.nodes for d in node.devices]
    n_hp = sum(1 for a in apps if a.priority == Priority.HIGH)
    n_dev = len(caps)
    ref = max(caps)
    per_dev_hp = max(1, -(-n_hp // n_dev))          # ceil: HP per device
    out = []
    for a in apps:
        if a.priority != Priority.HIGH:
            out.append(0)
        elif a.quota_slices > 0:
            out.append(min(a.quota_slices, ref))
        else:
            out.append(max(1, ref // per_dev_hp))
    return out


def place_cluster(cluster: ClusterSpec, apps: list[AppSpec],
                  router: str = "frag_aware",
                  node_router: str = "least_loaded"
                  ) -> list[tuple[int, int]]:
    """Return (node, device) for each app.  Deterministic.

    The four node routers generalize verbatim (members = nodes, capacities
    = node slice totals; demand priced on ``nodes[0].devices[0]``), then
    ``node_router`` places within each node.  ``frag_aware`` instead works
    on the flat device list: best-fit-decreasing of HP guarantees — each
    guarantee goes to the device with the *least* free capacity that still
    fits it whole, so large contiguous blocks survive for large tenants
    (minimizing :func:`~repro.core.hierarchy.fragmentation`) and load
    consolidates onto few devices (idle devices stay cheap under the power
    cap).  BE tenants are spread by count, least-loaded-node first."""
    if router not in CLUSTER_ROUTERS:
        raise ValueError(f"unknown cluster router {router!r} "
                         f"(choose from {CLUSTER_ROUTERS})")
    n_apps = len(apps)
    if cluster.n_nodes == 1 and router != "frag_aware":
        node_pl = [0] * n_apps
    elif router != "frag_aware":
        caps = [node.total_slices for node in cluster.nodes]
        demands = None
        if router in ("least_loaded", "affinity"):
            ref = cluster.nodes[0].devices[0]
            demands = [demand_estimate(a, ref) for a in apps]
        node_pl = route(caps, apps, router, demands=demands)
    else:
        return _place_frag_aware(cluster, apps)
    out: list[tuple[int, int]] = [(0, 0)] * n_apps
    for ni, node in enumerate(cluster.nodes):
        sel = [i for i in range(n_apps) if node_pl[i] == ni]
        dev_pl = place(node, [apps[i] for i in sel], node_router)
        for i, d in zip(sel, dev_pl):
            out[i] = (ni, d)
    return out


def _place_frag_aware(cluster: ClusterSpec,
                      apps: list[AppSpec]) -> list[tuple[int, int]]:
    devs = [(ni, di, dev.n_slices)
            for ni, node in enumerate(cluster.nodes)
            for di, dev in enumerate(node.devices)]
    free = [cap for _, _, cap in devs]
    requests = _slice_requests(cluster, apps)
    out: list[tuple[int, int]] = [(0, 0)] * len(apps)
    hp_order = sorted((i for i, a in enumerate(apps)
                       if a.priority == Priority.HIGH),
                      key=lambda i: (-requests[i], i))
    for i in hp_order:
        fits = [d for d in range(len(devs)) if free[d] >= requests[i]]
        if fits:                            # best fit: tightest hole
            d = min(fits, key=lambda d: (free[d], d))
        else:                               # nothing fits whole: most free
            d = min(range(len(devs)), key=lambda d: (-free[d], d))
        out[i] = devs[d][:2]
        free[d] = max(0, free[d] - requests[i])
    # BE: spread by count (one per device beats two on one — they live on
    # stolen capacity), preferring devices with the most residual free
    be_count = [0] * len(devs)
    for i, a in enumerate(apps):
        if a.priority == Priority.HIGH:
            continue
        d = min(range(len(devs)),
                key=lambda d: (be_count[d], -free[d], d))
        out[i] = devs[d][:2]
        be_count[d] += 1
    return out


# ---------------------------------------------------------------------------
# Cluster power manager (per-device DVFS under one budget)
# ---------------------------------------------------------------------------

class ClusterPowerManager:
    """Coordinates per-device DVFS f-states under ``power_cap`` watts.

    An epoch hook on the cluster coordinator: at each epoch it snapshots
    every device's busy-slice count and HP backlog, plans per-device
    frequency caps with :func:`~repro.core.dvfs.plan_power_budget`, and
    applies them — through the governor's ``f_cap`` where a policy runs its
    own DVFS (the local governor keeps optimizing underneath the cap), and
    directly through the simulator's f-switch machinery otherwise.  Mutates
    members, so its presence forces the interleaved run loop."""

    def __init__(self, device_members, cap: float, hp_floor: float):
        self.members = list(device_members)     # flat SimMembers
        self.specs = [m.sim.device for m in self.members]
        self.cap = cap
        self.hp_floor = hp_floor
        #: (t, projected_watts_before, projected_watts_after, min_f) per epoch
        self.log: list[tuple[float, float, float, float]] = []

    def __call__(self, now: float):
        active = [m.sim.held_slices() for m in self.members]
        hp = [m.pressure().hp_depth > 0 for m in self.members]
        before = sum(s.power(a, m.sim.freq) for s, a, m in
                     zip(self.specs, active, self.members))
        fs = plan_power_budget(self.specs, active, hp, self.cap,
                               hp_floor=self.hp_floor)
        for m, f in zip(self.members, fs):
            gov = getattr(m.policy, "governor", None)
            drives_dvfs = (gov is not None
                           and getattr(m.policy, "cfg", None) is not None
                           and getattr(m.policy.cfg, "dvfs", False))
            if gov is not None:
                gov.f_cap = f
            if not drives_dvfs:
                m.sim.set_frequency(f)
        after = sum(s.power(a, f) for s, a, f in
                    zip(self.specs, active, fs))
        self.log.append((now, before, after, min(fs)))


# ---------------------------------------------------------------------------
# Fragmentation sampling
# ---------------------------------------------------------------------------

class FragSampler:
    """Samples cluster-wide free-lists on the epoch grid and scores them
    with :func:`~repro.core.hierarchy.fragmentation`.

    Registered as a read-only *member hook*: in the interleaved loop every
    member is sampled at each global epoch; in the sequential fast path
    each member is sampled as its own run crosses the same epoch grid —
    identical values either way, because uncoupled members share no
    state."""

    def __init__(self, members, demands: list[int], epoch: float):
        self.members = list(members)
        self.demands = [d for d in demands if d > 0]
        self.epoch = epoch
        self._free: dict[int, dict[int, list[int]]] = {}

    def hook(self, mi: int, t: float):
        k = round(t / self.epoch)
        self._free.setdefault(k, {})[mi] = self.members[mi].free_snapshot()

    def series(self) -> list[tuple[float, float]]:
        out = []
        for k in sorted(self._free):
            row = self._free[k]
            if len(row) != len(self.members):
                continue                    # incomplete epoch (run edge)
            free = [f for mi in range(len(self.members)) for f in row[mi]]
            out.append((k * self.epoch, fragmentation(free, self.demands)))
        return out

    @property
    def mean(self) -> float:
        s = self.series()
        return sum(f for _, f in s) / len(s) if s else 0.0


# ---------------------------------------------------------------------------
# Coordinator + result + entry point
# ---------------------------------------------------------------------------

class ClusterCoordinator(HierarchyCoordinator):
    """The cluster tier: member nodes as interleaved event streams plus
    cross-node stealing, cluster power capping and fragmentation sampling.

    All mechanism is inherited from :class:`HierarchyCoordinator`; this
    class binds it to :class:`NodeMember`s and registers the power/frag
    hooks."""

    def __init__(self, cluster: ClusterSpec, placement: dict,
                 node_coords: list[NodeCoordinator],
                 config: Optional[ClusterConfig] = None):
        self.cluster = cluster
        self.node_coords = node_coords
        cfg = config or ClusterConfig()
        super().__init__([NodeMember(c) for c in node_coords], cfg,
                         MemberLedger(cluster.n_nodes, placement))
        self.device_members = [m for c in node_coords for m in c.members]
        self.power_manager: Optional[ClusterPowerManager] = None
        self.frag_sampler: Optional[FragSampler] = None
        if cfg.power_cap > 0:
            self.power_manager = ClusterPowerManager(
                self.device_members, cfg.power_cap, cfg.power_hp_floor)
            self.epoch_hooks.append(self.power_manager)

    def enable_frag_sampling(self, demands: list[int]):
        self.frag_sampler = FragSampler(self.members, demands,
                                        self.config.epoch)
        self.member_hooks.append(self.frag_sampler.hook)


class ClusterResult:
    """Aggregated result of one cluster run: per-node :class:`NodeResult`s
    plus cluster-level metrics with the familiar read surface
    (``client(name)``, ``clients``, ``energy``, ``utilization``,
    ``records``) and the cluster-only ones (``frag_series``,
    ``power_log``, cluster vs intra-node migration counts)."""

    def __init__(self, cluster: ClusterSpec, router: str,
                 placement: list[tuple[int, int]],
                 per_node: list[NodeResult],
                 coordinator: ClusterCoordinator):
        self.cluster = cluster
        self.router = router
        self.placement = placement
        self.per_node = per_node
        self.coordinator = coordinator
        self.ledger = coordinator.ledger
        self.migrations = self.ledger.n_migrations          # cross-node
        self.node_migrations = sum(r.migrations for r in per_node)
        self.horizon = per_node[0].horizon
        self.energy = sum(r.energy for r in per_node)
        self.busy_slice_seconds = sum(r.busy_slice_seconds
                                      for r in per_node)
        self.records = [rec for r in per_node for rec in r.records]
        self.clients = sorted((c for r in per_node for c in r.clients),
                              key=lambda c: c.cid)
        fs = coordinator.frag_sampler
        self.frag_series = fs.series() if fs else []
        self.frag_mean = fs.mean if fs else 0.0
        pm = coordinator.power_manager
        self.power_log = pm.log if pm else []

    @property
    def utilization(self) -> float:
        return self.busy_slice_seconds / (self.horizon
                                          * self.cluster.total_slices)

    def client(self, name: str):
        return next(c for c in self.clients if c.name == name)

    def node_of(self, name: str) -> int:
        """Node a named client was *initially* placed on (the cluster
        ledger's ``current`` has where migration left it)."""
        cid = self.client(name).cid
        return self.placement[cid][0]


def evaluate_cluster(system: str, cluster: ClusterSpec,
                     apps: list[AppSpec], *,
                     horizon: float = 30.0, seed: int = 0,
                     lithos_config=None, router: str = "frag_aware",
                     node_router: str = "least_loaded",
                     cluster_config: Optional[ClusterConfig] = None,
                     placement: Optional[list[tuple[int, int]]] = None,
                     engine: str = "ref",
                     collect_records: bool = True,
                     frag_sample: bool = True,
                     faults: Optional[FaultPlan] = None) -> ClusterResult:
    """Place ``apps`` across the cluster and run one
    :class:`NodeCoordinator` per node under a
    :class:`ClusterCoordinator`.

    Client ids are cluster-global (the original app order), so a tenant
    keeps the same workload random stream under every placement — exactly
    the node tier's contract, one level up.  ``placement`` pins
    (node, device) per app, bypassing both routers.  With no cluster-level
    mechanisms enabled (migration off, no power cap) member nodes are
    uncoupled and run sequentially — bit-for-bit the per-node evaluation;
    a 1-node cluster then reproduces ``evaluate_node`` exactly.

    ``faults`` addresses devices by *flat* index across the cluster
    (node 0's devices first, then node 1's, ...)."""
    cfg = cluster_config or ClusterConfig()
    if placement is None:
        placement = place_cluster(cluster, apps, router, node_router)
    assert len(placement) == len(apps)
    node_coords = []
    fault_base = 0
    for ni, node in enumerate(cluster.nodes):
        sel = [i for i, (n, _) in enumerate(placement) if n == ni]
        coord = build_node(system, node, [apps[i] for i in sel],
                           [placement[i][1] for i in sel],
                           horizon=horizon, seed=seed,
                           lithos_config=lithos_config,
                           node_config=cfg.node_config, engine=engine,
                           collect_records=collect_records, cids=sel,
                           faults=faults, fault_base=fault_base)
        fault_base += node.n_devices
        node_coords.append(coord)
    coord = ClusterCoordinator(
        cluster, {i: n for i, (n, _) in enumerate(placement)},
        node_coords, cfg)
    if frag_sample:
        coord.enable_frag_sampling(_slice_requests(cluster, apps))
    coord.run_loop()
    per_node = [NodeResult(node, node_router, nc.placement,
                           [SimResult(s) for s in nc.sims], nc.policies,
                           coordinator=nc)
                for node, nc in zip(cluster.nodes, node_coords)]
    return ClusterResult(cluster, router, list(placement), per_node, coord)
