"""Vectorized simulator core — slot-indexed arrays, bit-for-bit parity.

Drop-in engine for :class:`~repro.core.simulator.Simulator` built for raw
events/sec on large traces (the ROADMAP's cluster tier and million-request
open-loop runs).  The public API, semantics and float results are the
reference engine's, exactly:

* **Slot arrays** — every in-flight kernel occupies one slot in a set of
  parallel numpy arrays (overhead left, divisible fraction left, work
  terms, slices, interference, per-client slice-second accumulator).
  ``_advance`` becomes whole-array arithmetic instead of a Python loop over
  ``in_flight``; held-slice and tenant counts are maintained incrementally
  so ``free_slices()`` is O(1).
* **Batched completion times** — dispatches inside one event are queued and
  their ETAs computed as one vectorized evaluation of the roofline formula,
  flushed (in dispatch order, preserving heap tie-breaking) before any
  other heap push can interleave.
* **Pre-generated arrival streams** — per-client arrival lists are merged
  into one time-sorted array at ``start()`` instead of being pushed through
  the heap one event each.  The merge replicates the reference counter
  order (per-client blocks in client order, stable sort by time), and the
  stream competes with the heap under the reference tie rule: arrivals were
  pushed first in ``start()``, so an arrival wins every time tie against
  tick/end/runtime events.  ``_arr_gen`` detach/admit semantics are kept:
  stream entries carry generation 0, re-seeded arrivals from
  ``admit_client`` go through the heap with the current generation.
* **Incremental client sets** — clients notify the engine (via the
  ``Client._watch`` hook) whenever queue state changes; the engine keeps
  ready (dispatchable-kernel) and startable (can-begin-next-job) sets so
  policies and the job-start loop iterate candidates, not all clients.
  Policies opt in via ``getattr(sim, "vec", False)``; unknown policies fall
  back to reference-identical full scans.
* **Changes-only allocation protocol** — ``Policy.alloc_changes`` lets a
  policy promise which kernels may have changed allocation; the engine
  skips the per-kernel compare/reschedule scan when nothing could have.

Parity contract (asserted by tests/test_engine_vec.py on every tier-1
scenario): identical CompletionRecord streams (same kids, same floats),
identical energy integral, busy_slice_seconds and per-client slice_seconds.
All float accumulations keep the reference's per-event add order — numpy
elementwise double ops are IEEE-identical to the scalar ones, and no
pairwise-summed reduction is used where the reference accumulates
sequentially.

Engine constraint: at most one in-flight kernel per client (true of every
shipped policy — strict per-queue FIFO).  The per-client slice-second
accumulator relies on it; violations raise immediately.
"""
from __future__ import annotations

import heapq
import math
from bisect import bisect_left, insort
from typing import Optional

import numpy as np

from repro.core.queues import Client
from repro.core.simulator import ExecKernel, Simulator
from repro.core.types import CompletionRecord

_INF = float("inf")

_F_ARRAYS = ("_s_ov", "_s_div", "_s_cw", "_s_mw", "_s_nbf", "_s_muf",
             "_s_slf", "_s_int", "_s_css")
_I_ARRAYS = ("_s_sl", "_s_mu")


class VecSimulator(Simulator):
    vec = True

    def __init__(self, device, apps, policy, *, horizon: float = 30.0,
                 seed: int = 0, cids: Optional[list[int]] = None,
                 collect_records: bool = True, faults=()):
        # incremental aggregates mirroring the reference's per-event scans;
        # set before super().__init__ so policy.attach (called there) can
        # already use free_slices()/held_slices()
        self._held_total = 0                 # sum of in-flight ek.slices
        self._tenant_count: dict[int, int] = {}
        # deferred dispatch ETAs: (slot, kid), flushed in dispatch order
        self._eta_pending: list[tuple[int, int]] = []
        super().__init__(device, apps, policy, horizon=horizon, seed=seed,
                         cids=cids, collect_records=collect_records,
                         faults=faults)
        # slot capacity: most policies dispatch at most one kernel per
        # client AND one slice per kernel bounds in-flight by n_slices;
        # MPS-style policies can exceed this (0-slice kernels), which
        # _grow_slots absorbs on demand
        self._init_slots(max(1, min(len(self.clients),
                                    self.device.n_slices)))
        # merged arrival stream (built in start())
        self._arr_t_list: list[float] = []
        self._arr_cid_list: list[int] = []
        self._arr_ptr = 0
        self._arr_n = 0
        # incremental client sets
        for c in self.clients:
            c._watch = self
        self._reindex_clients()

    # -- slot management ------------------------------------------------------

    def _init_slots(self, cap: int):
        self._cap = cap
        z = np.zeros
        self._s_ov = z(cap)       # overhead_left
        self._s_div = z(cap)      # div_left
        self._s_cw = z(cap)       # c_work
        self._s_mw = z(cap)       # m_work
        self._s_nbf = np.ones(cap)   # n_blocks (float; benign 1 when free)
        self._s_muf = np.ones(cap)   # max_useful_slices (float mirror)
        self._s_slf = z(cap)      # slices (float mirror)
        self._s_int = np.ones(cap)   # interference factor
        self._s_css = z(cap)      # client slice_seconds accumulator
        self._s_sl = z(cap, dtype=np.int64)    # slices (exact busy sums)
        self._s_mu = z(cap, dtype=np.int64)    # max_useful (exact busy sums)
        self._s_act = z(cap, dtype=bool)       # slot occupied
        # cached drain rate d(div_left)/dt — a pure function of the slot's
        # work terms, slices, interference and the device frequency, so it
        # only moves on dispatch / allocation change / fswitch, not per
        # event.  0 for free slots and 0-slice kernels (ref speed() rule).
        self._s_speed = z(cap)
        self._tmp = z(cap)                     # masked-op scratch
        self._ek_of_slot: list[Optional[ExecKernel]] = [None] * cap
        self._slot_of_kid: dict[int, int] = {}
        self._free_slots = list(range(cap - 1, -1, -1))   # pop() -> slot 0 first

    def _grow_slots(self):
        old = self._cap
        new = max(4, old * 2)
        for name in _F_ARRAYS + ("_s_speed",):
            arr = np.zeros(new)
            arr[:old] = getattr(self, name)
            setattr(self, name, arr)
        self._tmp = np.zeros(new)
        for name in _I_ARRAYS:
            arr = np.zeros(new, dtype=np.int64)
            arr[:old] = getattr(self, name)
            setattr(self, name, arr)
        act = np.zeros(new, dtype=bool)
        act[:old] = self._s_act
        self._s_act = act
        self._s_nbf[old:] = 1.0
        self._s_muf[old:] = 1.0
        self._s_int[old:] = 1.0
        self._ek_of_slot.extend([None] * (new - old))
        self._free_slots.extend(range(new - 1, old - 1, -1))
        self._cap = new

    # -- incremental ready/startable sets -------------------------------------

    def _reindex_clients(self):
        self._pos = {c.cid: i for i, c in enumerate(self.clients)}
        self._ready_in: set[int] = set()
        self._ready_pos: list[tuple[int, int]] = []       # (pos, cid)
        self._ready_pri: list[tuple[int, int, int]] = []  # (-prio, pos, cid)
        self._startable: set[int] = set()
        for c in self.clients:
            self._client_refresh(c)

    def _client_refresh(self, c: Client):
        """Exact recompute of one client's set memberships (the Client
        ``_watch`` hook; called after every queue-state mutation)."""
        cid = c.cid
        pos = self._pos.get(cid)
        if pos is None:
            return                       # detached
        ready = c.peek() is not None
        if ready != (cid in self._ready_in):
            pk = (pos, cid)
            rk = (-int(c.spec.priority), pos, cid)
            if ready:
                self._ready_in.add(cid)
                insort(self._ready_pos, pk)
                insort(self._ready_pri, rk)
            else:
                self._ready_in.discard(cid)
                del self._ready_pos[bisect_left(self._ready_pos, pk)]
                del self._ready_pri[bisect_left(self._ready_pri, rk)]
        if c._startable_now():
            self._startable.add(cid)
        else:
            self._startable.discard(cid)

    def client_pos(self, cid: int) -> int:
        """Index of a client in the client list (the reference iteration
        order — stable-sort tiebreaker for policy candidate ordering)."""
        return self._pos[cid]

    def ready_clients(self) -> list[Client]:
        """Clients with a dispatchable kernel, in client-list order."""
        cb = self.client_by_id
        return [cb[cid] for _, cid in self._ready_pos]

    def ready_by_priority(self) -> list[Client]:
        """Ready clients ordered like ``sorted(clients, key=-priority)``
        (stable: priority desc, client-list position asc)."""
        cb = self.client_by_id
        return [cb[cid] for _, _, cid in self._ready_pri]

    # -- O(1) capacity queries -------------------------------------------------

    def held_slices(self) -> int:
        return self._held_total

    def free_slices(self) -> int:
        return max(0, self.device.n_slices - self.n_retired
                   - self._held_total)

    # -- dispatch interface ----------------------------------------------------

    def start_kernel(self, client, task, slices, *, slice_set=(),
                     stolen=False, t_submit=None) -> ExecKernel:
        phases = self.cost.phases(task.work)
        ek = ExecKernel(task=task, client=client, phases=phases,
                        t_submit=self.now if t_submit is None else t_submit,
                        t_start=self.now,
                        overhead_left=phases.overhead,
                        slices=max(0, slices), slice_set=slice_set,
                        stolen=stolen)
        self.in_flight[task.kid] = ek
        cid = client.cid
        if self._tenant_count.get(cid, 0):
            raise RuntimeError(
                "engine='vec' requires at most one in-flight kernel per "
                "client (strict per-queue FIFO); use engine='ref' for "
                "policies that dispatch deeper")
        self._tenant_count[cid] = 1
        self._held_total += ek.slices
        if not self._free_slots:
            self._grow_slots()
        slot = self._free_slots.pop()
        self._slot_of_kid[task.kid] = slot
        self._ek_of_slot[slot] = ek
        self._s_ov[slot] = phases.overhead
        self._s_div[slot] = 1.0
        self._s_cw[slot] = phases.c_work
        self._s_mw[slot] = phases.m_work
        self._s_nbf[slot] = float(phases.n_blocks)
        self._s_muf[slot] = float(phases.max_useful_slices)
        self._s_slf[slot] = float(ek.slices)
        self._s_int[slot] = 1.0
        self._s_css[slot] = client.slice_seconds
        self._s_sl[slot] = ek.slices
        self._s_mu[slot] = phases.max_useful_slices
        self._s_act[slot] = True
        self._s_speed[slot] = self._speed_scalar(slot)
        # completion time deferred: computed vectorized with the rest of
        # this event's dispatch batch, pushed before any later heap insert
        self._eta_pending.append((slot, task.kid))
        return ek

    def kill(self, kid: int):
        ek = self.in_flight.pop(kid, None)
        if ek is None:
            return None
        ek.gen += 1
        self._release_slot(kid, ek)
        return ek.task

    def _release_slot(self, kid: int, ek: ExecKernel):
        slot = self._slot_of_kid.pop(kid)
        # write back the per-client slice-second accumulator (same add
        # sequence as the reference's direct per-event accumulation)
        ek.client.slice_seconds = float(self._s_css[slot])
        self._ek_of_slot[slot] = None
        self._held_total -= ek.slices
        del self._tenant_count[ek.client.cid]
        self._s_ov[slot] = 0.0
        self._s_div[slot] = 0.0
        self._s_cw[slot] = 0.0
        self._s_mw[slot] = 0.0
        self._s_nbf[slot] = 1.0
        self._s_muf[slot] = 1.0
        self._s_slf[slot] = 0.0
        self._s_int[slot] = 1.0
        self._s_sl[slot] = 0
        self._s_mu[slot] = 0
        self._s_act[slot] = False
        self._s_speed[slot] = 0.0
        self._free_slots.append(slot)

    # -- completion-time computation -------------------------------------------

    def _speed_scalar(self, slot: int) -> float:
        """Drain rate of one slot — ``ExecKernel.speed``'s exact operation
        sequence (scalar IEEE doubles == numpy elementwise doubles), so the
        cached array is interchangeable with on-the-fly evaluation."""
        sl = float(self._s_slf[slot])
        if sl <= 0.0:
            return 0.0
        t_eff = max(min(sl, float(self._s_muf[slot])), 1.0)
        per_wave = t_eff * float(self.device.occupancy)
        ideal = float(self._s_nbf[slot]) / per_wave
        quant = math.ceil(ideal) / ideal
        t_div = max(float(self._s_cw[slot]) / self.freq,
                    float(self._s_mw[slot])) / t_eff * quant
        if t_div <= 0.0:
            return _INF
        return float(self._s_int[slot]) / t_div

    def _recompute_speeds(self):
        """Re-derive every slot's cached drain rate (frequency switched)."""
        t_eff = np.maximum(np.minimum(self._s_slf, self._s_muf), 1.0)
        per_wave = t_eff * float(self.device.occupancy)
        ideal = self._s_nbf / per_wave
        quant = np.ceil(ideal) / ideal
        t_div = np.maximum(self._s_cw / self.freq,
                           self._s_mw) / t_eff * quant
        sp = np.divide(self._s_int, t_div,
                       out=np.full(self._cap, np.inf), where=(t_div > 0.0))
        sp[self._s_sl <= 0] = 0.0
        sp[~self._s_act] = 0.0
        self._s_speed = sp

    def _etas_for(self, slots) -> np.ndarray:
        """Vectorized ``ExecKernel.eta`` over the cached drain rates."""
        idx = np.asarray(slots, dtype=np.intp)
        sp = self._s_speed[idx]
        div_t = np.divide(self._s_div[idx], sp,
                          out=np.zeros(len(idx)), where=(sp > 0.0))
        eta = self._s_ov[idx] + div_t      # div/inf == 0.0: overhead only
        eta[sp == 0.0] = np.inf            # slices <= 0: never completes
        return eta

    def _eta_scalar(self, slot: int) -> float:
        """Single-slot ``_etas_for`` without array round-trips.  Scalar
        IEEE double ops are the same correctly-rounded operations numpy
        applies elementwise, so results are bit-identical (div/inf == 0.0
        covers the overhead-only lane the masked divide produces)."""
        sp = float(self._s_speed[slot])
        if sp == 0.0:
            return _INF
        return float(self._s_ov[slot]) + float(self._s_div[slot]) / sp

    def _flush_etas(self):
        """Push completion events for the pending dispatch batch, in
        dispatch order (heap counters must match the reference's
        push-at-dispatch sequence)."""
        pend = self._eta_pending
        if not pend:
            return
        self._eta_pending = []
        live = [(slot, kid) for slot, kid in pend
                if self._slot_of_kid.get(kid) == slot]
        if not live:
            return
        if len(live) == 1:
            etas = [self._eta_scalar(live[0][0])]
        else:
            etas = self._etas_for([s for s, _ in live]).tolist()
        now = self.now
        for (slot, kid), eta in zip(live, etas):
            ek = self._ek_of_slot[slot]
            ek.gen += 1
            if eta != _INF:
                self._push(now + eta, "complete", (kid, ek.gen))

    def _schedule_completion(self, ek: ExecKernel):
        # flush first: any deferred dispatch pushes precede this one in the
        # reference's counter order
        if self._eta_pending:
            self._flush_etas()
        ek.gen += 1
        eta = self._eta_scalar(self._slot_of_kid[ek.task.kid])
        if eta != _INF:
            self._push(self.now + eta, "complete", (ek.task.kid, ek.gen))

    # -- state advance ---------------------------------------------------------

    def _advance(self, t_new: float):
        dt = t_new - self.now
        if dt <= 0:
            self.now = max(self.now, t_new)
            return
        if not self.in_flight:
            # busy == 0; adding dt*0 to the busy/css accumulators is the
            # identity, so only the energy integral needs the event
            self.energy += dt * self.device.power(0, self.freq)
            self.now = t_new
            return
        busy = int(np.minimum(self._s_sl, self._s_mu).sum())
        ns = self.device.n_slices
        if busy > ns:
            busy = ns
        self.energy += dt * self.device.power(busy, self.freq)
        self.busy_slice_seconds += dt * busy
        ov = self._s_ov
        o = np.minimum(ov, dt)
        ov -= o
        used = dt - o
        div = self._s_div
        # div[upd] = max(0, div - used*speed), masked so untouched lanes
        # never compute (0 * inf on an overhead-only free lane would warn)
        upd = (used > 0.0) & (div > 0.0)
        tmp = self._tmp
        np.multiply(used, self._s_speed, out=tmp, where=upd)
        np.subtract(div, tmp, out=tmp, where=upd)
        np.maximum(tmp, 0.0, out=tmp, where=upd)
        np.copyto(div, tmp, where=upd)
        self._s_css += dt * self._s_slf
        self.now = t_new

    # -- allocation application -------------------------------------------------

    def _apply_allocations(self):
        if self._eta_pending:
            self._flush_etas()
        pol = self.policy
        if not self.in_flight:
            return []
        alloc = pol.alloc_changes(self.now)
        if alloc is None:
            alloc = pol.allocations(self.now)     # unknown policy: full scan
        pen = pol.interference_penalty
        if pen:
            factor = max(0.3, 1.0 - pen * (len(self._tenant_count) - 1))
        else:
            factor = 1.0
        scan = bool(alloc)
        if not scan and pen:
            # factor changed for some co-resident kernel?  (vector test over
            # occupied slots — exactly the reference's per-kernel compare)
            d = np.abs(self._s_int - factor) > 1e-9
            scan = bool(np.any(d & self._s_act))
        if not scan:
            return []
        changed = []
        shrink = pol.allow_shrink
        for kid, ek in self.in_flight.items():
            s = alloc.get(kid, ek.slices)
            if s < 0:
                s = 0
            if not shrink and s < ek.slices:
                s = ek.slices              # blocks are non-preemptible
            if s != ek.slices or abs(factor - ek.interference) > 1e-9:
                slot = self._slot_of_kid[kid]
                self._held_total += s - ek.slices
                ek.slices = s
                ek.interference = factor
                self._s_sl[slot] = s
                self._s_slf[slot] = float(s)
                self._s_int[slot] = factor
                self._s_speed[slot] = self._speed_scalar(slot)
                changed.append(ek)
        for ek in changed:
            self._schedule_completion(ek)
        return changed

    def _complete(self, ek: ExecKernel):
        kid = ek.task.kid
        del self.in_flight[kid]
        self._release_slot(kid, ek)
        rec = CompletionRecord(task=ek.task, t_submit=ek.t_submit,
                               t_start=ek.t_start, t_end=self.now,
                               slices=ek.slices, freq=self.freq)
        if self.collect_records:
            self.records.append(rec)
        self.policy.on_complete(ek, rec)

    # -- fault injection ---------------------------------------------------------

    def _apply_fault(self, f) -> bool:
        """Vectorized transient_stall (the stall lands in the slot arrays,
        mirrored into the ExecKernel for any scalar reads); slice_retired
        and device_dead delegate to the reference implementation — kill()
        already releases slots and writes back client accumulators."""
        if f.kind != "transient_stall":
            return super()._apply_fault(f)
        self.fault_log.append((self.now, f))
        self._flush_etas()
        for ek in self.in_flight.values():
            slot = self._slot_of_kid[ek.task.kid]
            self._s_ov[slot] += f.duration
            ek.overhead_left = float(self._s_ov[slot])
            self._schedule_completion(ek)
        return False

    # -- frequency / migration plumbing (flush-before-push discipline) ----------

    def set_frequency(self, f: float):
        self._flush_etas()
        super().set_frequency(f)

    def schedule_release(self, cid: int, at: float):
        self._flush_etas()
        super().schedule_release(cid, at)

    def detach_client(self, cid: int):
        c = super().detach_client(cid)
        c._watch = None
        self._reindex_clients()       # positions shifted by list removal
        return c

    def admit_client(self, client, after: float):
        self._flush_etas()
        super().admit_client(client, after)
        client._watch = self
        self._pos[client.cid] = len(self.clients) - 1
        self._client_refresh(client)

    # -- main loop ---------------------------------------------------------------

    def start(self):
        """Seed tick/end events and build the merged arrival stream.

        The merge replicates the reference heap-counter order: per-client
        arrival blocks concatenated in client order (closed-loop t=0.0
        entry after the client's own list, as in the reference ``start``),
        then a stable sort by time — equal times keep push order, exactly
        the reference counter tie-break."""
        ts, cs = [], []
        for c in self.clients:
            a = c.arrivals()
            if a:
                ts.append(np.asarray(a, dtype=np.float64))
                cs.append(np.full(len(a), c.cid, dtype=np.int64))
            if c.closed_loop:
                ts.append(np.zeros(1))
                cs.append(np.full(1, c.cid, dtype=np.int64))
        if ts:
            t = np.concatenate(ts)
            cid = np.concatenate(cs)
            order = np.argsort(t, kind="stable")
            self._arr_t_list = t[order].tolist()
            self._arr_cid_list = cid[order].tolist()
        else:
            self._arr_t_list = []
            self._arr_cid_list = []
        self._arr_ptr = 0
        self._arr_n = len(self._arr_t_list)
        if self.policy.tick_interval > 0:
            self._push(self.policy.tick_interval, "tick", None)
        self._push(self.horizon, "end", None)
        # fault events after end, matching the reference push order: at
        # equal timestamps faults yield to stream arrivals (arrivals win
        # heap ties) and beat runtime-pushed ticks/completions (larger
        # counters) — identical ordering in both engines
        for f in self._fault_events:
            self._push(f.t, "fault", f)

    def peek_time(self) -> Optional[float]:
        if self.done:
            return None
        self._flush_etas()
        ht = self._heap[0][0] if self._heap else None
        at = (self._arr_t_list[self._arr_ptr]
              if self._arr_ptr < self._arr_n else None)
        if ht is None:
            return at
        if at is None:
            return ht
        return at if at <= ht else ht

    def step_event(self) -> bool:
        if self.done:
            return False
        heap = self._heap
        ai = self._arr_ptr
        # pick the next event: stream arrival vs heap top.  Arrivals win
        # every time tie — in the reference they were pushed first in
        # start(), so their counters are lower than any tick/end/runtime
        # push at the same timestamp.
        if ai < self._arr_n and (not heap
                                 or self._arr_t_list[ai] <= heap[0][0]):
            t = self._arr_t_list[ai]
            self._arr_ptr = ai + 1
            kind = "arrival"
            payload = (self._arr_cid_list[ai], 0)
        elif heap:
            t, _, kind, payload = heapq.heappop(heap)
        else:
            self.done = True
            return False
        self.events += 1
        if t > self.horizon and kind != "end":
            return True                     # post-horizon stragglers: skip
        self._advance(t)
        if kind == "end":
            # final write-back of in-flight kernels' client accumulators
            for ek in self.in_flight.values():
                slot = self._slot_of_kid[ek.task.kid]
                ek.client.slice_seconds = float(self._s_css[slot])
            self.done = True
            return False
        if kind == "arrival":
            cid, gen = payload
            c = self.client_by_id.get(cid)
            if c is None or gen != self._arr_gen.get(cid, 0):
                return True                 # migrated away: stale arrival
            c.on_arrival(self.now)
        elif kind == "complete":
            kid, gen = payload
            ek = self.in_flight.get(kid)
            if ek is None or ek.gen != gen:
                return True
            slot = self._slot_of_kid[kid]
            if self._s_ov[slot] > 1e-12 or self._s_div[slot] > 1e-9:
                self._schedule_completion(ek)   # stale estimate; refresh
                return True
            self._complete(ek)
        elif kind == "fswitch":
            self.freq = payload
            self._pending_freq = None
            self._recompute_speeds()
            for ek in self.in_flight.values():
                self._schedule_completion(ek)
        elif kind == "tick":
            self.policy.on_tick(self.now)
            self._flush_etas()      # on_tick pushes precede the re-push
            self._push(self.now + self.policy.tick_interval, "tick", None)
        elif kind == "unhold":
            self.policy.release_hold(payload)
        elif kind == "fault":
            if self._apply_fault(payload):
                self.done = True        # device dead: event stream ends
                return False
        self._apply_allocations()
        self.policy.step(self.now)
        if self._startable:
            cb = self.client_by_id
            pos = self._pos
            for c in sorted((cb[cid] for cid in tuple(self._startable)
                             if cid in cb), key=lambda c: pos[c.cid]):
                c.start_next_job(self.now)
        self.policy.step(self.now)
        self._apply_allocations()
        if self._eta_pending:
            self._flush_etas()
        return True
