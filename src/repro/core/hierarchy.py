"""Level-agnostic scheduling hierarchy: the machinery shared by every tier.

The paper's control plane manages one GPU; PR 1/2 scaled it to a node of N
devices, and the same concepts recur one level up (node -> cluster) — so the
machinery lives here, parameterized over *members*, and each tier
instantiates it:

    tier      coordinator                     member
    node      repro.core.node.NodeCoordinator     one device (sim + policy)
    cluster   repro.core.cluster.ClusterCoordinator  one node (NodeCoordinator)

What a tier reuses:

* **Pressure sampling** — every member reports a :class:`Pressure` sample
  (HP queue depth, free-list occupancy, active tenants) at a fixed epoch;
  the saturated/lender thresholds are level-independent knobs.
* **Placement routing** — :func:`route` implements the four routers
  (round_robin / least_loaded / quota_aware / affinity) over plain member
  capacities, so the same policies place tenants on devices within a node
  or on nodes within a cluster.
* **Lending protocol** — :class:`HierarchyCoordinator` interleaves member
  event streams in global time order, samples pressure per epoch, and
  migrates one best-effort client's launch queue from a saturated member to
  an idle one through the drain -> export -> admit pipeline the members
  implement.  Every move lands in a
  :class:`~repro.core.slices.MemberLedger`, extending the SliceMap
  conservation story to the coordinator's level.
* **Fragmentation** — :func:`fragmentation` scores a free-list snapshot
  against a tenant demand distribution (the FRAG-style objective of
  "Power- and Fragmentation-aware Online Scheduling for GPU Datacenters"):
  the expected fraction of free capacity stranded in fragments too small to
  host a random tenant's guarantee.

Adding a future level (cluster -> region, region -> fleet) means writing
one :class:`Member` adapter over the lower tier's coordinator — the
coordinator below already exposes the event-stream interface
(``start``/``peek_time``/``step_event``) this tier consumes, exactly as a
:class:`~repro.core.simulator.Simulator` does (DESIGN.md §7).
"""
from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass
from typing import Optional, Sequence

ROUTERS = ("round_robin", "least_loaded", "quota_aware", "affinity")


# ---------------------------------------------------------------------------
# Pressure (the lending protocol's signal, any level)
# ---------------------------------------------------------------------------

@dataclass
class Pressure:
    """One member's pressure sample."""

    hp_depth: int                   # HP jobs pending or in progress
    free_frac: float                # free-list occupancy (idle fraction)
    active: int                     # clients with work
    # Latency-critical decode backlog (HP serving tenants: waiting
    # requests + the in-flight iteration).  Counted *on top of* hp_depth,
    # so decode pressure weighs double in the saturation threshold —
    # a token behind in a decode queue is user-visible TBT, not just
    # queueing.  0 for every pre-LLM workload (legacy behavior intact).
    decode_depth: int = 0


# ---------------------------------------------------------------------------
# Placement routing (level-agnostic: members are capacities)
# ---------------------------------------------------------------------------

def _argmin_load(loads: list[float], caps: Sequence[int]) -> int:
    """Member with the lowest capacity-normalized load (ties: lowest id)."""
    base = caps[0]
    return min(range(len(caps)),
               key=lambda d: (loads[d] * base / caps[d], d))


def _effective_quota(app, caps: Sequence[int], n_hp: int, d: int = 0,
                     headroom: Optional[int] = None) -> int:
    """A-priori estimate of the guarantee ``app`` would need on member
    ``d`` (capacity ``caps[d]``).  Explicit quotas are exact (clamped to
    the member); derived HP shares split the unreserved headroom by the
    hierarchy-wide HP count — conservative, mirroring the
    reserve-explicit-first structure of ``quotas_from_apps``."""
    if app.quota_slices > 0:
        return min(app.quota_slices, caps[d])
    from repro.core.types import Priority
    if app.priority == Priority.HIGH:
        cap = caps[d] if headroom is None else max(0, headroom)
        return cap // max(1, n_hp)
    return 0


def route(caps: Sequence[int], apps: list,
          router: str = "least_loaded",
          demands: Optional[list[float]] = None) -> list[int]:
    """Return the member index for each app.  Deterministic.

    ``caps`` are member capacities in slices (devices of a node, or nodes
    of a cluster); ``demands`` are per-app load estimates in member-0
    capacity units (required by least_loaded / affinity — the caller
    prices them, typically via ``node.demand_estimate``)."""
    from repro.core.types import Priority

    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r} (choose from {ROUTERS})")
    n = len(caps)
    if n == 1:
        return [0] * len(apps)
    if router == "round_robin":
        return [i % n for i in range(len(apps))]

    placement = [0] * len(apps)
    if router == "least_loaded":
        assert demands is not None, "least_loaded needs demand estimates"
        loads = [0.0] * n
        for i in sorted(range(len(apps)), key=lambda i: (-demands[i], i)):
            d = _argmin_load(loads, caps)
            placement[i] = d
            loads[d] += demands[i]
        return placement

    if router == "quota_aware":
        n_hp = sum(1 for a in apps if a.priority == Priority.HIGH)
        # quota demand is sized per target member (capacities may differ),
        # derived shares against the headroom left after reservations
        headroom = list(caps)
        quota_on = lambda i, d: _effective_quota(apps[i], caps, n_hp, d,
                                                 headroom=headroom[d])
        be_count = [0] * n
        hp_order = sorted((i for i, a in enumerate(apps)
                           if a.priority == Priority.HIGH),
                          key=lambda i: (-max(_effective_quota(
                              apps[i], caps, n_hp, d) for d in range(n)), i))
        for i in hp_order:
            # member where the guarantee still fits; else most headroom
            fits = [d for d in range(n) if headroom[d] >= quota_on(i, d)]
            cands = fits or range(n)
            d = min(cands, key=lambda d: (-headroom[d], d))
            placement[i] = d
            headroom[d] -= quota_on(i, d)
        for i, a in enumerate(apps):
            if a.priority == Priority.HIGH:
                continue
            d = min(range(n), key=lambda d: (be_count[d], -headroom[d], d))
            placement[i] = d
            be_count[d] += 1
        return placement

    if router == "affinity":
        assert demands is not None, "affinity needs demand estimates"
        groups: dict[str, list[int]] = {}
        for i, a in enumerate(apps):
            groups.setdefault(a.cfg.name, []).append(i)
        gload = {g: sum(demands[i] for i in ids) for g, ids in groups.items()}
        loads = [0.0] * n
        for g in sorted(groups, key=lambda g: (-gload[g], g)):
            d = _argmin_load(loads, caps)
            for i in groups[g]:
                placement[i] = d
            loads[d] += gload[g]
        return placement

    raise AssertionError(f"unhandled router {router!r}")  # ROUTERS is closed


# ---------------------------------------------------------------------------
# Fragmentation (FRAG-style free-list score, any level)
# ---------------------------------------------------------------------------

def fragmentation(free: Sequence[int], demands: Sequence[int]) -> float:
    """Expected fraction of free capacity stranded w.r.t. a demand
    distribution.

    ``free`` is a free-list snapshot — idle slices per leaf member (each
    device of a node; each device of each node of a cluster).  ``demands``
    are representative per-tenant slice requests (the placement-time
    guarantee estimates).  A member's free slices are *stranded* for a
    demand it cannot host whole, so

        F = sum_d free_d * P(demand > free_d) / sum_d free_d

    F = 0 when every fragment fits every request, 1 when no request fits
    anywhere — the FRAG objective of arXiv 2412.17484 evaluated against
    the tenant population instead of a fixed task mix."""
    total = sum(free)
    if total <= 0 or not demands:
        return 0.0
    ds = sorted(demands)
    n = len(ds)
    stranded = sum(f * (n - bisect_right(ds, f)) / n for f in free)
    return stranded / total


# ---------------------------------------------------------------------------
# Member port (what a tier's coordinator needs from each member)
# ---------------------------------------------------------------------------

class Member:
    """One schedulable member of a hierarchy tier.

    A member is an event-stream (the :class:`~repro.core.simulator.Simulator`
    stepping interface) plus the lending-protocol hooks the coordinator
    drives.  ``repro.core.node.SimMember`` adapts one device (simulator +
    policy); ``repro.core.cluster.NodeMember`` adapts one node (a whole
    :class:`~repro.core.node.NodeCoordinator`) — the recursion that makes
    the hierarchy level-agnostic."""

    capacity: int = 0               # total slices
    horizon: float = 0.0

    # -- event stream -------------------------------------------------------

    def start(self):
        raise NotImplementedError

    def peek_time(self) -> Optional[float]:
        raise NotImplementedError

    def step_event(self) -> bool:
        raise NotImplementedError

    @property
    def done(self) -> bool:
        raise NotImplementedError

    def invalidate_peeks(self):
        """Drop any internally cached next-event times — the coordinator
        calls this after mutating the member from outside its own event
        loop (power capping, migration export/admit).  Leaf members keep
        no cache; a nested coordinator must drop its own."""

    # -- fault domain --------------------------------------------------------

    def failed(self) -> bool:
        """True once the member is permanently dead (``device_dead`` at the
        leaf; a nested tier is dead when every leaf below it is)."""
        return False

    def has_faults(self) -> bool:
        """True when a fault plan targets this member (forces the
        coordinator's interleaved loop — detection needs global time)."""
        return False

    def can_host(self, client) -> bool:
        """Placement filter for evacuees: False when the client's memory
        floor (KV cache) cannot fit on this member's surviving capacity."""
        return True

    # -- pressure / placement ----------------------------------------------

    def pressure(self) -> Pressure:
        raise NotImplementedError

    def free_snapshot(self) -> list[int]:
        """Idle slices per leaf member (len 1 for a device; one entry per
        device for a node) — the fragmentation metric's input."""
        raise NotImplementedError

    # -- migration protocol -------------------------------------------------

    def supports_migration(self) -> bool:
        return False

    def migration_candidates(self) -> list[int]:
        """Eligible BE client ids, ascending (no cooldown filter — the
        coordinator owns move history)."""
        return []

    def begin_drain(self, cid: int):
        raise NotImplementedError

    def abort_drain(self, cid: int):
        raise NotImplementedError

    def drain_dead(self, cid: int) -> bool:
        """True when the member hosting ``cid`` can no longer complete the
        drain (its horizon beat the kernel boundary)."""
        raise NotImplementedError

    def drained(self, cid: int) -> bool:
        raise NotImplementedError

    def clock(self, cid: int) -> float:
        """Clock of the leaf hosting ``cid`` (the arrival cutoff and the
        migration anchor are stamped with it)."""
        raise NotImplementedError

    def export_client(self, cid: int):
        """Remove a drained client; returns (client, priority, state)."""
        raise NotImplementedError

    def admit_client(self, client, priority, state, *, after: float,
                     release_at: float):
        """Admit a migrated client: warm-start from ``state``, re-seed
        arrivals strictly after ``after``, hold dispatch until
        ``release_at`` (the migration cost)."""
        raise NotImplementedError

    # -- invariants ---------------------------------------------------------

    def hosted_cids(self) -> list[int]:
        raise NotImplementedError

    def check(self):
        return True


@dataclass
class _PendingMigration:
    cid: int
    src: int
    dst: int
    t_decided: float


class HierarchyCoordinator:
    """Runs members as interleaved event streams and drives one tier of the
    lending protocol.

    The loop always steps the member with the globally earliest pending
    event, so member clocks stay within one event of each other — the
    precondition for sampling a coherent tier-wide pressure snapshot every
    ``config.epoch`` seconds and for moving a launch queue between members
    without time travel.

    Migration of a chosen best-effort client proceeds in three phases:

    1. **hold** — the source stops planning new kernels for the client;
       its in-flight kernel drains at the atom boundary;
    2. **drain / export** — once drained (observed after a source event),
       the client object moves with its launch queue, pending jobs and RNG
       stream intact, together with its warm policy state;
    3. **admit / warm** — the target admits the client immediately (so it
       is never unaccounted for), imports the warm state, and holds
       dispatch for ``migration_cost`` seconds.

    Every move is recorded in a :class:`~repro.core.slices.MemberLedger`;
    ``config.validate`` re-checks tier-wide conservation at every epoch.

    Epoch *hooks* (fragmentation sampling, power capping) run before the
    migration decision at each epoch.  When the tier needs no cross-member
    coupling at all — migration off and no mutating hooks — ``run_loop``
    takes a sequential fast path: each member runs to completion
    independently (bit-for-bit identical, since uncoupled members share no
    state), with read-only per-member hooks still fired at epoch
    boundaries.
    """

    def __init__(self, members: list[Member], config, ledger):
        self.members = members
        self.config = config
        self.ledger = ledger
        self._pending: Optional[_PendingMigration] = None
        self._last_move: dict[int, float] = {}
        self.migration_log: list[tuple[float, int, int, int]] = []
        #: cids a higher tier is draining — excluded from this tier's
        #: migration candidates (no two coordinators move one client)
        self.frozen: set[int] = set()
        #: called at every epoch, before migration decisions, with the
        #: epoch timestamp — may mutate members (forces interleaving)
        self.epoch_hooks: list = []
        #: read-only per-member hooks: f(member_index, t) — safe in the
        #: sequential fast path because uncoupled members evolve
        #: independently, so member-local state at time t is identical
        #: whether sampled globally or during the member's own run
        self.member_hooks: list = []
        #: fault domain: evacuate a dead member's tenants automatically
        #: (the ctl daemon turns this off and drives recovery through its
        #: own PREEMPT -> REQUEUE job machinery instead)
        self.auto_evacuate = True
        self.failed_members: set[int] = set()
        self.fault_log: list[tuple[float, int]] = []    # (t, member)
        #: cids left on a dead member because no live destination existed
        self.stranded: set[int] = set()
        self._started = False
        self._done = False

    # -- thresholds ----------------------------------------------------------

    def _saturated(self, p: Pressure) -> bool:
        cfg = self.config
        return (p.hp_depth + p.decode_depth >= cfg.hp_depth_hi
                or (p.free_frac <= cfg.free_lo and p.active >= 2))

    def _lender(self, p: Pressure) -> bool:
        cfg = self.config
        return (p.hp_depth == 0 and p.decode_depth == 0
                and p.free_frac >= cfg.free_hi)

    # -- migration decisions -------------------------------------------------

    def _candidates(self, m: Member, now: float) -> list[int]:
        cool = self.config.cooldown
        return [cid for cid in m.migration_candidates()
                if cid not in self.frozen
                and now >= self._last_move.get(cid, -1e18) + cool]

    def _epoch(self, now: float):
        cfg = self.config
        for hook in self.epoch_hooks:
            hook(now)
        if self.epoch_hooks:
            self.invalidate_peeks()     # mutating hooks may push events
        for hook in self.member_hooks:
            for mi in range(len(self.members)):
                hook(mi, now)
        if not self._migrate:
            return
        if cfg.validate:
            self.check()
        if self._pending is not None:
            return                          # one drain in progress at a time
        if cfg.max_migrations and \
                self.ledger.n_migrations >= cfg.max_migrations:
            return
        if not all(m.supports_migration() for m in self.members):
            return
        press = [m.pressure() for m in self.members]
        lenders = [d for d in range(len(self.members))
                   if self._lender(press[d])]
        if not lenders:
            return
        # most-pressured saturated member with an eligible BE tenant first
        sat = sorted((d for d in range(len(self.members))
                      if self._saturated(press[d])),
                     key=lambda d: (-press[d].hp_depth, press[d].free_frac,
                                    d))
        for src in sat:
            cands = self._candidates(self.members[src], now)
            if not cands:
                continue
            dst = max((d for d in lenders if d != src),
                      key=lambda d: (press[d].free_frac, -d), default=None)
            if dst is None:
                continue
            cid = cands[0]
            self._pending = _PendingMigration(cid, src, dst, now)
            self.members[src].begin_drain(cid)    # begin draining
            self._maybe_execute(src)              # may already be drained
            return

    def _maybe_execute(self, d: int):
        """Execute the pending migration once its client has drained (called
        after every event on the source member)."""
        pm = self._pending
        if pm is None or pm.src != d:
            return
        src, dst = self.members[pm.src], self.members[pm.dst]
        if src.drain_dead(pm.cid):              # horizon beat the drain
            src.abort_drain(pm.cid)
            self._pending = None
            return
        if not src.drained(pm.cid):
            return
        # The migration is anchored at the *decision-or-later* instant: a
        # saturated member's clock (its last processed event) can lag the
        # epoch that decided the move, and stamping the ledger / cooldown /
        # cost with the stale clock would erode the cooldown window and
        # over-count donated seconds.  The arrival cutoff, by contrast, is
        # exactly what the source actually processed (its own clock).
        src_now = src.clock(pm.cid)
        t_mig = max(src_now, pm.t_decided)
        client, priority, state = src.export_client(pm.cid)
        dst.admit_client(client, priority, state, after=src_now,
                         release_at=t_mig + self.config.migration_cost)
        self.ledger.migrate(pm.cid, pm.dst, t_mig)
        self._last_move[pm.cid] = t_mig
        self.migration_log.append((t_mig, pm.cid, pm.src, pm.dst))
        self._dirty_deep(pm.src)        # export/admit mutated both heaps
        self._dirty_deep(pm.dst)
        self._pending = None

    # -- fault handling ------------------------------------------------------

    def _on_member_failed(self, d: int, now: float):
        """A member just died: take it out of the interleaved loop, cancel
        any migration touching it, and (unless the tier above owns
        recovery) evacuate its tenants to surviving members."""
        self.failed_members.add(d)
        self._active.discard(d)
        self._peek_dirty.add(d)
        self.fault_log.append((now, d))
        pm = self._pending
        if pm is not None and (pm.src == d or pm.dst == d):
            if pm.src != d and not self.members[pm.src].failed():
                self.members[pm.src].abort_drain(pm.cid)
            self._pending = None
        if self.auto_evacuate:
            self._evacuate(d, now)

    def _evacuate(self, d: int, now: float):
        """Move every tenant off dead member ``d``.

        The device_dead fault already REEF-reset in-flight work back onto
        the launch queues, so every hosted client is drained — export is
        immediate, no hold/drain phase.  HP evacuees move first (their
        guarantees re-derive against the fullest destination pools) and
        each lands on the most-free survivor whose capacity can hold its
        KV memory floor (``can_host``)."""
        m = self.members[d]
        cids = list(m.hosted_cids())
        if not cids:
            return
        dsts = sorted(i for i in self._active
                      if i != d and i not in self.failed_members
                      and self.members[i].supports_migration())
        if not dsts or not m.supports_migration():
            self.stranded.update(cids)
            return
        exports = []
        for cid in cids:
            src_now = m.clock(cid)
            client, priority, state = m.export_client(cid)
            self.frozen.discard(cid)
            exports.append((cid, src_now, client, priority, state))
        exports.sort(key=lambda e: (-int(e[3]), e[0]))   # HP first
        for cid, src_now, client, priority, state in exports:
            fit = [i for i in dsts if self.members[i].can_host(client)]
            cands = fit or dsts
            dst = max(cands, key=lambda i: (
                self.members[i].pressure().free_frac, -i))
            self.members[dst].admit_client(
                client, priority, state, after=src_now,
                release_at=now + self.config.migration_cost)
            self.ledger.migrate(cid, dst, now)
            self._last_move[cid] = now
            self.migration_log.append((now, cid, d, dst))
            self._dirty_deep(dst)
        self._dirty_deep(d)

    # -- invariants ----------------------------------------------------------

    def check(self) -> bool:
        """Tier-wide conservation: every client hosted exactly once, the
        ledger agrees with the live hosting map, and each member's own
        invariants hold."""
        hosted: dict[int, int] = {}
        for d, m in enumerate(self.members):
            for cid in m.hosted_cids():
                assert cid not in hosted, f"client {cid} hosted twice"
                hosted[cid] = d
        self.ledger.check(hosted)
        for m in self.members:
            m.check()
        return True

    # -- interleaved run loop ------------------------------------------------

    @property
    def done(self) -> bool:
        return self._done

    def start(self):
        cfg = self.config
        for m in self.members:
            m.start()
        self._migrate = cfg.migration and len(self.members) > 1
        self._epochs_on = bool(self._migrate or self.epoch_hooks
                               or self.member_hooks)
        self._next_epoch = cfg.epoch if self._epochs_on else float("inf")
        self.horizon = self.members[0].horizon
        self._active = set(range(len(self.members)))
        # next-event-time cache: recomputed only for members that were
        # stepped or externally mutated, so the interleaved loop's
        # globally-earliest scan costs O(members) comparisons instead of
        # O(members) nested peeks per event
        self._peek_cache: list = [None] * len(self.members)
        self._peek_dirty = set(self._active)
        self._started = True

    def _member_peek(self, i: int):
        if i in self._peek_dirty:
            self._peek_cache[i] = self.members[i].peek_time()
            self._peek_dirty.discard(i)
        return self._peek_cache[i]

    def _dirty_deep(self, i: int):
        """Mark member ``i``'s next-event time stale after an *external*
        mutation (the member's own internal caches are stale too)."""
        self._peek_dirty.add(i)
        self.members[i].invalidate_peeks()

    def invalidate_peeks(self):
        if self._started:
            for i in range(len(self.members)):
                self._dirty_deep(i)

    def peek_time(self) -> Optional[float]:
        if self._done:
            return None
        times = [t for i in self._active
                 if (t := self._member_peek(i)) is not None]
        return min(times) if times else None

    def step_event(self) -> bool:
        """Process exactly one member event (one iteration of the
        interleaved loop).  Returns False once the run is over."""
        if self._done:
            return False
        if not self._started:
            self.start()
        d = min((i for i in self._active
                 if self._member_peek(i) is not None),
                key=lambda i: (self._member_peek(i), i), default=None)
        if d is None:
            self._finish()
            return False
        t = self._member_peek(d)
        while t >= self._next_epoch and self._next_epoch <= self.horizon:
            self._epoch(self._next_epoch)
            self._next_epoch += self.config.epoch
        if not self.members[d].step_event():
            self._active.discard(d)
        self._peek_dirty.add(d)         # own step: internal caches are fine
        if d not in self.failed_members and self.members[d].failed():
            self._on_member_failed(d, t)
        if self._migrate:
            self._maybe_execute(d)
        if not self._active:
            self._finish()
        return True

    def _finish(self):
        if self._done:
            return
        self._done = True
        if self.config.validate:
            self.check()

    def _needs_interleave(self) -> bool:
        cfg = self.config
        return bool((cfg.migration and len(self.members) > 1)
                    or self.epoch_hooks
                    or any(m.has_faults() for m in self.members))

    def run_loop(self):
        """Run every member to completion.  Uncoupled tiers (migration off,
        no mutating epoch hooks) take the sequential fast path."""
        if self._needs_interleave():
            if not self._started:
                self.start()
            while self.step_event():
                pass
            return
        # sequential fast path: members share no state, so running them to
        # completion one by one is bit-for-bit the interleaved run (the
        # parity property the node tier's tests establish); read-only
        # member hooks still fire at the epoch grid, seeing exactly the
        # state a global sample at that instant would have seen
        if not self._started:
            self.start()
        cfg = self.config
        for mi, m in enumerate(self.members):
            next_epoch = cfg.epoch if self.member_hooks else float("inf")
            while True:
                t = m.peek_time()
                if t is None:
                    break
                while t >= next_epoch and next_epoch <= self.horizon:
                    for hook in self.member_hooks:
                        hook(mi, next_epoch)
                    next_epoch += cfg.epoch
                if not m.step_event():
                    break
            self._active.discard(mi)
        self._finish()
