"""§4.5 Hardware right-sizing.

Per operator node, fit the Amdahl curve ``l(t) = m/t + b`` from two online
observations — latency with the full allocation and with one slice — then
pick the minimal ``t`` whose predicted slowdown vs. the full allocation stays
within the *latency slip* factor ``k`` (e.g. 1.1 = 10%).

Outlier filtering: before the model is consulted, an occupancy bound caps
useful slices at ``ceil(n_blocks / occupancy)`` — tiny grids cannot use a
large allocation no matter what the curve says.  The atomizer's block counts
provide n_blocks; occupancy comes from the device spec (the TPU analogue of
the CUDA occupancy API: VMEM-resident tiles per core).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.types import CompletionRecord, KernelTask


@dataclass
class ScalingFit:
    m: float = 0.0
    b: float = 0.0
    # raw two-point observations: slices -> latency
    points: dict[int, float] = field(default_factory=dict)
    fitted: bool = False

    def latency(self, t: int) -> float:
        return self.m / max(1, t) + self.b

    def r_squared(self, obs: dict[int, float]) -> float:
        if len(obs) < 2:
            return 1.0
        ys = list(obs.values())
        mean = sum(ys) / len(ys)
        ss_tot = sum((y - mean) ** 2 for y in ys) or 1e-24
        ss_res = sum((y - self.latency(t)) ** 2 for t, y in obs.items())
        return 1.0 - ss_res / ss_tot


class RightSizer:
    """Online per-node Amdahl fitting + slip-bounded allocation shrinking."""

    def __init__(self, full_slices: int, occupancy: int, slip: float = 1.1):
        self.full = full_slices
        self.occupancy = occupancy
        self.slip = slip
        self.fits: dict[tuple[int, int], ScalingFit] = {}
        self.extra_obs: dict[tuple[int, int], dict[int, float]] = {}
        # KV-cache memory floor per client (cid -> min slices): a tenant
        # whose KV footprint needs N slices' worth of HBM can never be
        # right-sized below N — shrinking its compute share below its
        # memory share would evict live cache.  Maintained by the
        # scheduler from Client.kv_bytes; relaxes as requests complete.
        self.memory_floor: dict[int, int] = {}

    def set_memory_floor(self, cid: int, floor: int):
        if floor > 1:
            self.memory_floor[cid] = floor
        else:
            self.memory_floor.pop(cid, None)

    # -- learning -------------------------------------------------------------

    def observe(self, rec: CompletionRecord):
        task = rec.task
        lat = rec.latency
        if task.atom_of is not None:
            _, _, n = task.atom_of
            lat = lat * n                      # full-kernel equivalent
        if rec.freq < 0.999:
            return                             # fit at f_max only
        fit = self.fits.setdefault(task.key(), ScalingFit())
        fit.points[rec.slices] = lat
        self.extra_obs.setdefault(task.key(), {})[rec.slices] = lat
        if len(fit.points) >= 2 and not fit.fitted:
            self._fit(fit)

    def _fit(self, fit: ScalingFit):
        # two-point fit per the paper: prefer (max slices, min slices)
        ts = sorted(fit.points)
        t_lo, t_hi = ts[0], ts[-1]
        if t_lo == t_hi:
            return
        l_lo, l_hi = fit.points[t_lo], fit.points[t_hi]
        m = (l_lo - l_hi) / (1.0 / t_lo - 1.0 / t_hi)
        b = l_hi - m / t_hi
        fit.m, fit.b = max(m, 0.0), max(b, 0.0)
        fit.fitted = True

    # -- probing protocol -------------------------------------------------------

    def probe_allocation(self, task: KernelTask, default: int,
                         predicted_full: Optional[float] = None,
                         probe_latency_cap: float = 25e-3) -> Optional[int]:
        """If this node still needs a calibration point, return the slice
        count to run it at (full first, then the low point); else None.

        The low point is 1 slice per the paper; for kernels whose 1-slice
        run would exceed ``probe_latency_cap`` (long kernels on short
        serving deadlines) the low point is raised so the probe stays
        bounded — the two-point fit works from any two distinct points."""
        fit = self.fits.get(task.key())
        if fit is None or not fit.points:
            return min(default, self.occupancy_bound(task), self.full)
        if not fit.fitted:
            have = set(fit.points)
            low = 1
            if predicted_full is not None:
                t_hi = max(have)
                est_1 = predicted_full * t_hi
                if est_1 > probe_latency_cap:
                    low = max(1, math.ceil(est_1 / probe_latency_cap))
                    if low > t_hi // 2:
                        # a bounded probe would land too close to t_hi for
                        # a usable two-point fit (wave-quantization noise
                        # dominates adjacent points) — leave this kernel
                        # unfitted; the occupancy filter still applies
                        fit.m, fit.b = 0.0, fit.points[t_hi]
                        fit.fitted = True
                        return None
            if low not in have:
                return low
        return None

    # -- allocation decision ----------------------------------------------------

    def occupancy_bound(self, task: KernelTask) -> int:
        """Filtering heuristic: max slices a grid can use (§4.5)."""
        return max(1, math.ceil(task.work.n_blocks / self.occupancy))

    def decide(self, task: KernelTask, allocated: int) -> int:
        """Minimal slice count within the latency-slip budget, clamped to
        the owning tenant's KV-cache memory floor."""
        floor = self.memory_floor.get(task.client_id, 1)
        clamp = lambda t: min(allocated, max(t, floor))  # noqa: E731
        bound = self.occupancy_bound(task)
        if bound < allocated:
            return clamp(bound)
        fit = self.fits.get(task.key())
        if fit is None or not fit.fitted:
            return allocated
        l_full = fit.latency(allocated)
        if l_full <= 0 or fit.m <= 0:
            return clamp(min(allocated, bound))
        budget = self.slip * l_full
        if budget <= fit.b:
            return allocated
        t_min = fit.m / (budget - fit.b)
        return clamp(max(1, math.ceil(t_min)))

    # -- reporting ---------------------------------------------------------------

    def weighted_r2(self) -> float:
        """Kernel-runtime-weighted mean R^2 of the fits (§7.2 accuracy)."""
        tot_w = tot = 0.0
        for key, fit in self.fits.items():
            if not fit.fitted or fit.m <= 0:
                continue
            obs = self.extra_obs.get(key, {})
            if len(obs) < 3:
                continue
            w = sum(obs.values())
            tot += w * fit.r_squared(obs)
            tot_w += w
        return tot / tot_w if tot_w else float("nan")
