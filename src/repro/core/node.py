"""Node layer: placement/routing of tenants across a multi-device node.

The paper's control plane manages one GPU; production serving runs fleets.
This layer generalizes the timing plane to a :class:`NodeSpec` of N devices:
each device runs its *own* policy instance (own SliceMap, quotas, predictor,
governor — no hidden cross-device state), and a router decides which device
each tenant's launch queue is pinned to.  Placement is per-client, not
per-job: a client's stream lives on one device for the simulation, matching
how serving frameworks pin model replicas (cross-device migration is the
elastic follow-on in the ROADMAP).

Router policies:

* ``round_robin``   — arrival-order striping; the no-information baseline.
* ``least_loaded``  — greedy bin-packing of estimated demand (service
  seconds/second from the cost model; closed-loop trainers count as a full
  device since they soak whatever they are given), largest first, onto the
  device with the lowest capacity-normalized load.
* ``quota_aware``   — place by guarantee headroom: HP tenants go where their
  quota still fits un-oversubscribed (largest quota first); BE tenants are
  spread by count (they run on stolen capacity, so one per device beats two
  on one).
* ``affinity``      — tenants sharing a model architecture co-locate
  (predictor/right-sizer state is per-(queue, ordinal): co-located replicas
  of one model warm the same operating regime), groups balanced by load.

Client ids are node-global (the original app order), so a tenant keeps the
same workload random stream under every placement — router comparisons see
identical arrivals, not resampled ones.

Cross-device TPC stealing (the node-level lending protocol) lives in
:class:`NodeCoordinator`: the per-device simulators run as interleaved event
streams in global time order, per-device pressure is sampled at a fixed
epoch, and an idle device lends its capacity to a saturated one by hosting a
best-effort tenant's launch queue (drained at a kernel boundary, charged a
migration cost, predictor warmed from the source device's observations).
Every donation is recorded in a :class:`~repro.core.slices.NodeLedger`
mirroring the SliceMap lend ledger, so conservation invariants extend across
devices.  With ``NodeConfig.migration=False`` (default) the coordinator
never intervenes and the run is bit-for-bit the historical independent
per-device evaluation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.simulator import (Policy, SimResult, Simulator,
                                  make_simulator)
from repro.core.slices import NodeLedger
from repro.core.types import NodeConfig, NodeSpec, Priority
from repro.core.workloads import AppSpec, mean_demand

ROUTERS = ("round_robin", "least_loaded", "quota_aware", "affinity")


_demand_cache: dict[tuple, float] = {}


def demand_estimate(app: AppSpec, device) -> float:
    """Expected device-utilization fraction of one tenant (cost-model based,
    the same calibration the benchmarks use).  Load-based routers price
    demand on ``devices[0]`` and normalize loads by each device's capacity
    (`_argmin_load`), which is exact for homogeneous nodes and proportional
    for heterogeneous ones.  Memoized: mean_demand samples whole job traces
    through the cost model and is invariant per (workload, device)."""
    if app.kind == "train" or app.rps <= 0:
        return 1.0                       # closed loop: soaks a device
    key = (app.name, app.cfg.name, app.kind, app.batch, app.fusion,
           tuple(app.prompt_mix), app.decode_tokens, app.seed, app.rps,
           device)            # DeviceSpec is frozen: full profile, not just
                              # n_slices (cost model prices flops/bw too)
    if key not in _demand_cache:
        _demand_cache[key] = min(1.0, app.rps * mean_demand(app, device))
    return _demand_cache[key]


def _argmin_load(loads: list[float], node: NodeSpec) -> int:
    """Device with the lowest capacity-normalized load (ties: lowest id)."""
    base = node.devices[0].n_slices
    return min(range(node.n_devices),
               key=lambda d: (loads[d] * base / node.devices[d].n_slices, d))


def _effective_quota(app: AppSpec, node: NodeSpec, n_hp: int, d: int = 0,
                     headroom: int = None) -> int:
    """A-priori estimate of the guarantee ``app`` would need on device ``d``.

    Explicit quotas are exact: ``quotas_from_apps`` reserves them first,
    clamped to the device.  Derived HP shares depend on the final
    co-placement (they split whatever the explicit reservations leave), so
    the router estimates them from the device's *unreserved headroom* at
    decision time, divided by the node-wide HP count — conservative, and it
    tracks the reserve-explicit-first structure of ``quotas_from_apps``
    without duplicating its arithmetic against a fixed capacity."""
    dev = node.devices[d]
    if app.quota_slices > 0:
        return min(app.quota_slices, dev.n_slices)
    if app.priority == Priority.HIGH:
        cap = dev.n_slices if headroom is None else max(0, headroom)
        return cap // max(1, n_hp)
    return 0


def place(node: NodeSpec, apps: list[AppSpec],
          router: str = "least_loaded") -> list[int]:
    """Return the device index for each app.  Deterministic."""
    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r} (choose from {ROUTERS})")
    n = node.n_devices
    if n == 1:
        return [0] * len(apps)
    if router == "round_robin":
        return [i % n for i in range(len(apps))]

    placement = [0] * len(apps)
    if router == "least_loaded":
        demands = [demand_estimate(a, node.devices[0]) for a in apps]
        loads = [0.0] * n
        for i in sorted(range(len(apps)), key=lambda i: (-demands[i], i)):
            d = _argmin_load(loads, node)
            placement[i] = d
            loads[d] += demands[i]
        return placement

    if router == "quota_aware":
        n_hp = sum(1 for a in apps if a.priority == Priority.HIGH)
        # quota demand is sized per target device (devices may differ),
        # derived shares against the headroom left after reservations
        headroom = [dev.n_slices for dev in node.devices]
        quota_on = lambda i, d: _effective_quota(apps[i], node, n_hp, d,
                                                 headroom=headroom[d])
        be_count = [0] * n
        hp_order = sorted((i for i, a in enumerate(apps)
                           if a.priority == Priority.HIGH),
                          key=lambda i: (-max(_effective_quota(
                              apps[i], node, n_hp, d) for d in range(n)), i))
        for i in hp_order:
            # device where the guarantee still fits; else most headroom
            fits = [d for d in range(n) if headroom[d] >= quota_on(i, d)]
            cands = fits or range(n)
            d = min(cands, key=lambda d: (-headroom[d], d))
            placement[i] = d
            headroom[d] -= quota_on(i, d)
        for i, a in enumerate(apps):
            if a.priority == Priority.HIGH:
                continue
            d = min(range(n), key=lambda d: (be_count[d], -headroom[d], d))
            placement[i] = d
            be_count[d] += 1
        return placement

    if router == "affinity":
        groups: dict[str, list[int]] = {}
        for i, a in enumerate(apps):
            groups.setdefault(a.cfg.name, []).append(i)
        demands = [demand_estimate(a, node.devices[0]) for a in apps]
        gload = {g: sum(demands[i] for i in ids) for g, ids in groups.items()}
        loads = [0.0] * n
        for g in sorted(groups, key=lambda g: (-gload[g], g)):
            d = _argmin_load(loads, node)
            for i in groups[g]:
                placement[i] = d
            loads[d] += gload[g]
        return placement

    raise AssertionError(f"unhandled router {router!r}")  # ROUTERS is closed


@dataclass
class _Pressure:
    """One device's pressure sample (the lending protocol's signal)."""

    hp_depth: int                   # HP jobs pending or in progress
    free_frac: float                # SliceMap free-list occupancy
    active: int                     # clients with work


@dataclass
class _PendingMigration:
    cid: int
    src: int
    dst: int
    t_decided: float


class NodeCoordinator:
    """Runs the per-device simulators as interleaved event streams and
    drives the node-level lending protocol (cross-device TPC stealing).

    The loop always steps the simulator with the globally earliest pending
    event, so device clocks stay within one event of each other — the
    precondition for sampling a coherent node-wide pressure snapshot every
    ``config.epoch`` seconds and for moving a launch queue between devices
    without time travel.

    Migration of a chosen best-effort client proceeds in three phases:

    1. **hold** — the source policy stops planning new kernels for the
       client; its in-flight kernel drains at the atom boundary;
    2. **detach / export** — once drained (observed after a source event),
       the client object moves with its launch queue, pending jobs and RNG
       stream intact; the source policy exports its predictor observations;
    3. **admit / warm** — the target admits the client immediately (so it is
       never unaccounted for), imports the warm predictor state, and holds
       dispatch for ``migration_cost`` seconds — the price of moving a
       replica's working state between devices.

    Every move is recorded in a :class:`NodeLedger`; ``config.validate``
    additionally re-checks cross-device conservation at every epoch.
    """

    def __init__(self, node: NodeSpec, placement: list[int],
                 sims: list[Simulator], policies: list[Policy],
                 config: Optional[NodeConfig] = None):
        self.node = node
        self.placement = placement
        self.sims = sims
        self.policies = policies
        self.config = config or NodeConfig()
        self.ledger = NodeLedger(node.n_devices, placement)
        self._pending: Optional[_PendingMigration] = None
        self._last_move: dict[int, float] = {}
        self.migration_log: list[tuple[float, int, int, int]] = []

    # -- pressure sampling ---------------------------------------------------

    def _pressure(self, d: int) -> _Pressure:
        sim = self.sims[d]
        hp_depth = 0
        active = 0
        for c in sim.clients:
            busy = (c.current is not None or bool(c.pending)
                    or c.outstanding > 0)
            if busy or c.closed_loop:
                active += 1
            if c.priority == Priority.HIGH:
                hp_depth += len(c.pending) + (1 if c.current is not None
                                              else 0)
        sm = getattr(self.policies[d], "slices", None)
        if sm is not None:
            cnt = sm.counts()
            free = cnt["owned_idle"] + cnt["pool_idle"]
        else:
            free = sim.free_slices()
        return _Pressure(hp_depth, free / sim.device.n_slices, active)

    def _saturated(self, p: _Pressure) -> bool:
        cfg = self.config
        return (p.hp_depth >= cfg.hp_depth_hi
                or (p.free_frac <= cfg.free_lo and p.active >= 2))

    def _lender(self, p: _Pressure) -> bool:
        cfg = self.config
        return p.hp_depth == 0 and p.free_frac >= cfg.free_hi

    # -- migration decisions -------------------------------------------------

    def _candidates(self, d: int, now: float) -> list[int]:
        """BE clients on device ``d`` eligible to move: have work, not in a
        cooldown window, and own no slices — ownership is static for a
        simulation, so a BE tenant with an *explicit* quota (legitimately
        granted by ``quotas_from_apps``) is pinned like an HP tenant.
        Ascending cid — deterministic."""
        sm = getattr(self.policies[d], "slices", None)
        out = []
        for c in self.sims[d].clients:
            if c.priority == Priority.HIGH:
                continue
            if sm is not None and sm.owned_by(c.cid) > 0:
                continue
            if not (c.closed_loop or c.current is not None or c.pending):
                continue
            if now < self._last_move.get(c.cid, -1e18) + self.config.cooldown:
                continue
            out.append(c.cid)
        return sorted(out)

    def _epoch(self, now: float):
        cfg = self.config
        if cfg.validate:
            self.check()
        if self._pending is not None:
            return                          # one drain in progress at a time
        if cfg.max_migrations and \
                self.ledger.n_migrations >= cfg.max_migrations:
            return
        if not all(p.supports_migration for p in self.policies):
            return
        press = [self._pressure(d) for d in range(self.node.n_devices)]
        lenders = [d for d in range(self.node.n_devices)
                   if self._lender(press[d])]
        if not lenders:
            return
        # most-pressured saturated device with an eligible BE tenant first
        sat = sorted((d for d in range(self.node.n_devices)
                      if self._saturated(press[d])),
                     key=lambda d: (-press[d].hp_depth, press[d].free_frac,
                                    d))
        for src in sat:
            cands = self._candidates(src, now)
            if not cands:
                continue
            dst = max((d for d in lenders if d != src),
                      key=lambda d: (press[d].free_frac, -d), default=None)
            if dst is None:
                continue
            cid = cands[0]
            self._pending = _PendingMigration(cid, src, dst, now)
            self.policies[src].hold_client(cid)   # begin draining
            self._maybe_execute(src)              # may already be drained
            return

    def _maybe_execute(self, d: int):
        """Execute the pending migration once its client has drained (called
        after every event on the source device)."""
        pm = self._pending
        if pm is None or pm.src != d:
            return
        src_sim, dst_sim = self.sims[pm.src], self.sims[pm.dst]
        if src_sim.done:                        # horizon beat the drain
            self.policies[pm.src].release_hold(pm.cid)
            self._pending = None
            return
        if not self.policies[pm.src].client_drained(pm.cid):
            return
        # The migration is anchored at the *decision-or-later* instant: a
        # saturated device's clock (its last processed event) can lag the
        # epoch that decided the move, and stamping the ledger / cooldown /
        # cost with the stale clock would erode the cooldown window and
        # over-count donated seconds.  The arrival cutoff, by contrast, is
        # exactly what the source actually processed (its own clock).
        t_mig = max(src_sim.now, pm.t_decided)
        state = self.policies[pm.src].export_client_state(pm.cid)
        client = src_sim.detach_client(pm.cid)
        self.policies[pm.dst].import_client_state(pm.cid, client.priority,
                                                  state)
        dst_sim.admit_client(client, after=src_sim.now)
        self.policies[pm.dst].hold_client(pm.cid)
        dst_sim.schedule_release(pm.cid, t_mig + self.config.migration_cost)
        self.ledger.migrate(pm.cid, pm.dst, t_mig)
        self._last_move[pm.cid] = t_mig
        self.migration_log.append((t_mig, pm.cid, pm.src, pm.dst))
        self._pending = None

    # -- invariants ----------------------------------------------------------

    def check(self) -> bool:
        """Cross-device conservation: every client hosted exactly once, the
        ledger agrees with the live hosting map, and each device's SliceMap
        invariants hold."""
        hosted: dict[int, int] = {}
        for d, sim in enumerate(self.sims):
            for c in sim.clients:
                assert c.cid not in hosted, f"client {c.cid} hosted twice"
                hosted[c.cid] = d
        self.ledger.check(hosted)
        for p in self.policies:
            sm = getattr(p, "slices", None)
            if sm is not None:
                sm.check()
        return True

    # -- interleaved run loop ------------------------------------------------

    def run(self) -> list[SimResult]:
        cfg = self.config
        for sim in self.sims:
            sim.start()
        migrate = cfg.migration and self.node.n_devices > 1
        next_epoch = cfg.epoch if migrate else float("inf")
        horizon = self.sims[0].horizon
        active = set(range(len(self.sims)))
        while active:
            d = min((i for i in active if self.sims[i].peek_time() is not None),
                    key=lambda i: (self.sims[i].peek_time(), i), default=None)
            if d is None:
                break
            t = self.sims[d].peek_time()
            while migrate and t >= next_epoch and next_epoch <= horizon:
                self._epoch(next_epoch)
                next_epoch += cfg.epoch
            if not self.sims[d].step_event():
                active.discard(d)
            if migrate:
                self._maybe_execute(d)
        if cfg.validate:
            self.check()
        return [SimResult(sim) for sim in self.sims]


class NodeResult:
    """Aggregated result of one node run: per-device :class:`SimResult`s
    plus node-level metrics with the same read surface as a SimResult
    (``client(name)``, ``clients``, ``energy``, ``utilization``,
    ``records``)."""

    def __init__(self, node: NodeSpec, router: str, placement: list[int],
                 results: list[SimResult], policies: list,
                 coordinator: Optional[NodeCoordinator] = None):
        self.node = node
        self.router = router
        self.placement = placement
        self.per_device = results
        self.policies = policies
        self.policy = policies[0] if policies else None
        self.coordinator = coordinator
        self.ledger = coordinator.ledger if coordinator else None
        self.migrations = self.ledger.n_migrations if self.ledger else 0
        self.final_placement = (
            [self.ledger.current[cid] for cid in sorted(self.ledger.current)]
            if self.ledger else list(placement))
        self.horizon = results[0].horizon
        self.policy_name = results[0].policy_name
        self.energy = sum(r.energy for r in results)
        self.busy_slice_seconds = sum(r.busy_slice_seconds for r in results)
        self.records = [rec for r in results for rec in r.records]
        self.clients = sorted((c for r in results for c in r.clients),
                              key=lambda c: c.cid)

    @property
    def utilization(self) -> float:
        return self.busy_slice_seconds / (self.horizon
                                          * self.node.total_slices)

    def client(self, name: str):
        return next(c for c in self.clients if c.name == name)

    def device_of(self, name: str) -> int:
        """Device index a named client was *initially* placed on (see
        ``final_placement`` for where migration left it)."""
        cid = self.client(name).cid
        return self.placement[cid]


def evaluate_node(system: str, node: NodeSpec, apps: list[AppSpec], *,
                  horizon: float = 30.0, seed: int = 0,
                  lithos_config=None, router: str = "least_loaded",
                  node_config: Optional[NodeConfig] = None,
                  placement: Optional[list[int]] = None,
                  engine: str = "ref",
                  collect_records: bool = True) -> NodeResult:
    """Route ``apps`` across the node and run one simulator + policy
    instance per device as interleaved event streams under a
    :class:`NodeCoordinator`.  With migration disabled (the default
    ``node_config``) devices share nothing, so the interleaved run is
    exactly the historical independent per-device evaluation; with
    ``node_config.migration=True`` the coordinator lends idle devices'
    capacity to saturated ones by migrating best-effort launch queues.

    ``placement`` overrides the router's decision (benchmarks pin
    adversarial placements with it)."""
    from repro.core.lithos import make_policy

    if placement is None:
        placement = place(node, apps, router)
    assert len(placement) == len(apps) and \
        all(0 <= d < node.n_devices for d in placement)
    sims: list[Simulator] = []
    policies = []
    for d, dev in enumerate(node.devices):
        idx = [i for i, p in enumerate(placement) if p == d]
        dev_apps = [apps[i] for i in idx]
        policy = make_policy(system, dev, dev_apps,
                             lithos_config=lithos_config, cids=idx)
        sim = make_simulator(dev, dev_apps, policy, engine=engine,
                             horizon=horizon, seed=seed, cids=idx,
                             collect_records=collect_records)
        sims.append(sim)
        policies.append(policy)
    coord = NodeCoordinator(node, list(placement), sims, policies,
                            config=node_config)
    results = coord.run()
    return NodeResult(node, router, list(placement), results, policies,
                      coordinator=coord)
