"""Node layer: placement/routing of tenants across a multi-device node.

The paper's control plane manages one GPU; production serving runs fleets.
This layer generalizes the timing plane to a :class:`NodeSpec` of N devices:
each device runs its *own* policy instance (own SliceMap, quotas, predictor,
governor — no hidden cross-device state), and a router decides which device
each tenant's launch queue is pinned to.  Placement is per-client, not
per-job: a client's stream lives on one device for the simulation, matching
how serving frameworks pin model replicas (cross-device migration is the
elastic follow-on in the ROADMAP).

Router policies (the algorithms live in :func:`repro.core.hierarchy.route`,
shared with the cluster tier, which routes tenants onto *nodes* with the
same four policies):

* ``round_robin``   — arrival-order striping; the no-information baseline.
* ``least_loaded``  — greedy bin-packing of estimated demand (service
  seconds/second from the cost model; closed-loop trainers count as a full
  device since they soak whatever they are given), largest first, onto the
  device with the lowest capacity-normalized load.
* ``quota_aware``   — place by guarantee headroom: HP tenants go where their
  quota still fits un-oversubscribed (largest quota first); BE tenants are
  spread by count (they run on stolen capacity, so one per device beats two
  on one).
* ``affinity``      — tenants sharing a model architecture co-locate
  (predictor/right-sizer state is per-(queue, ordinal): co-located replicas
  of one model warm the same operating regime), groups balanced by load.

Client ids are node-global (the original app order), so a tenant keeps the
same workload random stream under every placement — router comparisons see
identical arrivals, not resampled ones.

Cross-device TPC stealing (the node-level lending protocol) is one
instantiation of the level-agnostic
:class:`~repro.core.hierarchy.HierarchyCoordinator`: each device is a
:class:`SimMember` (simulator + policy), the coordinator interleaves their
event streams in global time order, samples per-device pressure at a fixed
epoch, and lends an idle device's capacity to a saturated one by hosting a
best-effort tenant's launch queue (drained at a kernel boundary, charged a
migration cost, predictor warmed from the source device's observations).
Every donation is recorded in a :class:`~repro.core.slices.MemberLedger`
mirroring the SliceMap lend ledger, so conservation invariants extend
across devices.  With ``NodeConfig.migration=False`` (default) the
coordinator never intervenes and the run is bit-for-bit the historical
independent per-device evaluation.  The whole node is itself a member one
level up: :mod:`repro.core.cluster` wraps a NodeCoordinator's stepping
interface to build clusters of nodes.
"""
from __future__ import annotations

from typing import Optional

from repro.core.hierarchy import (ROUTERS, HierarchyCoordinator, Member,
                                  Pressure, route)
from repro.core.simulator import (Policy, SimResult, Simulator,
                                  make_simulator)
from repro.core.slices import MemberLedger
from repro.core.types import FaultPlan, NodeConfig, NodeSpec, Priority
from repro.core.workloads import AppSpec, kv_floor_slices, mean_demand

_Pressure = Pressure                # historical name


_demand_cache: dict[tuple, float] = {}


def demand_estimate(app: AppSpec, device) -> float:
    """Expected device-utilization fraction of one tenant (cost-model based,
    the same calibration the benchmarks use).  Load-based routers price
    demand on ``devices[0]`` and normalize loads by each device's capacity
    (`_argmin_load`), which is exact for homogeneous nodes and proportional
    for heterogeneous ones.  Memoized: mean_demand samples whole job traces
    through the cost model and is invariant per (workload, device)."""
    if app.kind == "train" or app.rps <= 0:
        return 1.0                       # closed loop: soaks a device
    key = (app.name, app.cfg.name, app.kind, app.batch, app.fusion,
           tuple(app.prompt_mix), app.decode_tokens, app.seed, app.rps,
           device)            # DeviceSpec is frozen: full profile, not just
                              # n_slices (cost model prices flops/bw too)
    if key not in _demand_cache:
        _demand_cache[key] = min(1.0, app.rps * mean_demand(app, device))
    return _demand_cache[key]


def place(node: NodeSpec, apps: list[AppSpec],
          router: str = "least_loaded") -> list[int]:
    """Return the device index for each app.  Deterministic.  Thin wrapper
    over the level-agnostic :func:`repro.core.hierarchy.route`: the node
    prices demand on ``devices[0]`` and hands the router plain capacities."""
    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r} (choose from {ROUTERS})")
    caps = [dev.n_slices for dev in node.devices]
    demands = None
    if router in ("least_loaded", "affinity") and node.n_devices > 1:
        demands = [demand_estimate(a, node.devices[0]) for a in apps]
    return route(caps, apps, router, demands=demands)


class SimMember(Member):
    """One device as a hierarchy member: a simulator plus its policy.

    The leaf adapter — pressure comes from the live client queues and the
    policy's SliceMap free-list, and the migration protocol maps straight
    onto the PR 2 plumbing (policy hold/drain/export + simulator
    detach/admit/release)."""

    def __init__(self, sim: Simulator, policy: Policy):
        self.sim = sim
        self.policy = policy
        self.capacity = sim.device.n_slices

    # -- event stream -------------------------------------------------------

    @property
    def horizon(self) -> float:
        return self.sim.horizon

    def start(self):
        self.sim.start()

    def peek_time(self):
        return self.sim.peek_time()

    def step_event(self) -> bool:
        return self.sim.step_event()

    @property
    def done(self) -> bool:
        return self.sim.done

    # -- fault domain --------------------------------------------------------

    def failed(self) -> bool:
        return getattr(self.sim, "dead", False)

    def has_faults(self) -> bool:
        return bool(getattr(self.sim, "_fault_events", ()))

    def can_host(self, client) -> bool:
        """A decode tenant's KV memory floor must fit on the surviving
        (non-retired) capacity — evacuation never lands a tenant where its
        live cache cannot."""
        if self.failed():
            return False
        surviving = self.sim.device.n_slices - self.sim.n_retired
        floor = kv_floor_slices(client.spec.cfg, self.sim.device,
                                getattr(client, "kv_bytes", 0.0))
        return floor <= surviving

    # -- pressure / placement ----------------------------------------------

    def _free(self) -> int:
        if self.failed():
            return 0                    # a dead device lends nothing
        sm = getattr(self.policy, "slices", None)
        if sm is not None:
            cnt = sm.counts()
            return cnt["owned_idle"] + cnt["pool_idle"]
        return self.sim.free_slices()

    def pressure(self) -> Pressure:
        sim = self.sim
        hp_depth = 0
        active = 0
        decode_depth = 0
        for c in sim.clients:
            cbs = c.cbs
            busy = (c.current is not None or bool(c.pending)
                    or c.outstanding > 0
                    or (cbs is not None and cbs.has_work))
            if busy or c.closed_loop:
                active += 1
            if c.priority == Priority.HIGH:
                depth = len(c.pending) + (1 if c.current is not None else 0)
                hp_depth += depth
                # decode HP backlog is latency-critical (per-token TBT):
                # continuous tenants' waiting requests + in-flight
                # iteration, and disaggregated-decode tenants' queues
                if cbs is not None:
                    decode_depth += len(cbs.waiting) + (
                        1 if c.current is not None else 0)
                elif c.spec.kind == "llm_decode":
                    decode_depth += depth
        return Pressure(hp_depth, self._free() / sim.device.n_slices, active,
                        decode_depth)

    def free_snapshot(self) -> list[int]:
        return [self._free()]

    # -- migration protocol -------------------------------------------------

    def supports_migration(self) -> bool:
        return self.policy.supports_migration

    def migration_candidates(self) -> list[int]:
        """BE clients eligible to move: have work and own no slices —
        ownership is static for a simulation, so a BE tenant with an
        *explicit* quota (legitimately granted by ``quotas_from_apps``) is
        pinned like an HP tenant.  Ascending cid — deterministic."""
        sm = getattr(self.policy, "slices", None)
        out = []
        for c in self.sim.clients:
            if c.priority == Priority.HIGH:
                continue
            if sm is not None and sm.owned_by(c.cid) > 0:
                continue
            if not (c.closed_loop or c.current is not None or c.pending):
                continue
            out.append(c.cid)
        return sorted(out)

    def begin_drain(self, cid: int):
        self.policy.hold_client(cid)

    def abort_drain(self, cid: int):
        self.policy.release_hold(cid)

    def drain_dead(self, cid: int) -> bool:
        return self.sim.done                # horizon beat the drain

    def drained(self, cid: int) -> bool:
        return self.policy.client_drained(cid)

    def clock(self, cid: int) -> float:
        return self.sim.now

    def export_client(self, cid: int):
        state = self.policy.export_client_state(cid)
        client = self.sim.detach_client(cid)
        return client, client.priority, state

    def admit_client(self, client, priority, state, *, after: float,
                     release_at: float):
        self.policy.import_client_state(client.cid, priority, state)
        self.sim.admit_client(client, after=after)
        self.policy.hold_client(client.cid)
        self.sim.schedule_release(client.cid, release_at)

    # -- invariants ---------------------------------------------------------

    def hosted_cids(self) -> list[int]:
        return [c.cid for c in self.sim.clients]

    def check(self):
        sm = getattr(self.policy, "slices", None)
        if sm is not None:
            sm.check()
        return True


class NodeCoordinator(HierarchyCoordinator):
    """The node tier: per-device simulators as interleaved event streams
    plus the node-level lending protocol (cross-device TPC stealing).

    All mechanism — the globally-earliest-event loop, epoch pressure
    sampling, hold -> drain -> export -> admit migration, ledger
    conservation — lives in :class:`HierarchyCoordinator`; this class binds
    it to devices and keeps the node-tier construction/read surface
    (``sims``, ``policies``, ``run() -> [SimResult]``).
    """

    def __init__(self, node: NodeSpec, placement, sims: list[Simulator],
                 policies: list[Policy],
                 config: Optional[NodeConfig] = None):
        self.node = node
        self.placement = placement
        self.sims = sims
        self.policies = policies
        super().__init__([SimMember(s, p) for s, p in zip(sims, policies)],
                         config or NodeConfig(),
                         MemberLedger(node.n_devices, placement))

    def run(self) -> list[SimResult]:
        self.run_loop()
        return [SimResult(sim) for sim in self.sims]


class NodeResult:
    """Aggregated result of one node run: per-device :class:`SimResult`s
    plus node-level metrics with the same read surface as a SimResult
    (``client(name)``, ``clients``, ``energy``, ``utilization``,
    ``records``)."""

    def __init__(self, node: NodeSpec, router: str, placement,
                 results: list[SimResult], policies: list,
                 coordinator: Optional[NodeCoordinator] = None):
        self.node = node
        self.router = router
        self.placement = placement
        self.per_device = results
        self.policies = policies
        self.policy = policies[0] if policies else None
        self.coordinator = coordinator
        self.ledger = coordinator.ledger if coordinator else None
        self.migrations = self.ledger.n_migrations if self.ledger else 0
        self.final_placement = (
            [self.ledger.current[cid] for cid in sorted(self.ledger.current)]
            if self.ledger else list(placement))
        self.horizon = results[0].horizon
        self.policy_name = results[0].policy_name
        self.energy = sum(r.energy for r in results)
        self.busy_slice_seconds = sum(r.busy_slice_seconds for r in results)
        self.records = [rec for r in results for rec in r.records]
        self.clients = sorted((c for r in results for c in r.clients),
                              key=lambda c: c.cid)

    @property
    def utilization(self) -> float:
        return self.busy_slice_seconds / (self.horizon
                                          * self.node.total_slices)

    def client(self, name: str):
        return next(c for c in self.clients if c.name == name)

    def device_of(self, name: str) -> int:
        """Device index a named client was *initially* placed on (see
        ``final_placement`` for where migration left it)."""
        cid = self.client(name).cid
        return self.placement[cid]


def build_node(system: str, node: NodeSpec, apps: list[AppSpec],
               placement: list[int], *, horizon: float, seed: int = 0,
               lithos_config=None, node_config: Optional[NodeConfig] = None,
               engine: str = "ref", collect_records: bool = True,
               cids: Optional[list[int]] = None,
               faults: Optional[FaultPlan] = None,
               fault_base: int = 0) -> NodeCoordinator:
    """Construct one node's simulators + policies and wrap them in a
    :class:`NodeCoordinator` (not yet run).

    ``cids`` optionally assigns each app a global client id (the cluster
    tier passes cluster-global ids so tenants keep their workload streams
    under any node assignment); default is app order, the node-global ids
    ``evaluate_node`` has always used.  With explicit cids the coordinator's
    ledger is keyed by those ids (a dict placement).

    ``faults`` is a :class:`FaultPlan` whose ``member`` indices address
    flat device positions; ``fault_base`` is this node's offset into that
    flat numbering (the cluster tier passes the device count of the nodes
    before it)."""
    from repro.core.lithos import make_policy

    assert len(placement) == len(apps) and \
        all(0 <= d < node.n_devices for d in placement)
    ids = list(range(len(apps))) if cids is None else list(cids)
    sims: list[Simulator] = []
    policies = []
    for d, dev in enumerate(node.devices):
        on_d = [i for i, p in enumerate(placement) if p == d]
        idx = [ids[i] for i in on_d]
        dev_apps = [apps[i] for i in on_d]
        policy = make_policy(system, dev, dev_apps,
                             lithos_config=lithos_config, cids=idx)
        sim = make_simulator(dev, dev_apps, policy, engine=engine,
                             horizon=horizon, seed=seed, cids=idx,
                             collect_records=collect_records,
                             faults=(faults.events_for(fault_base + d)
                                     if faults is not None else ()))
        sims.append(sim)
        policies.append(policy)
    ledger_placement = (list(placement) if cids is None else
                        {ids[i]: placement[i] for i in range(len(apps))})
    return NodeCoordinator(node, ledger_placement, sims, policies,
                           config=node_config)


def evaluate_node(system: str, node: NodeSpec, apps: list[AppSpec], *,
                  horizon: float = 30.0, seed: int = 0,
                  lithos_config=None, router: str = "least_loaded",
                  node_config: Optional[NodeConfig] = None,
                  placement: Optional[list[int]] = None,
                  engine: str = "ref",
                  collect_records: bool = True,
                  faults: Optional[FaultPlan] = None) -> NodeResult:
    """Route ``apps`` across the node and run one simulator + policy
    instance per device as interleaved event streams under a
    :class:`NodeCoordinator`.  With migration disabled (the default
    ``node_config``) devices share nothing, so the interleaved run is
    exactly the historical independent per-device evaluation; with
    ``node_config.migration=True`` the coordinator lends idle devices'
    capacity to saturated ones by migrating best-effort launch queues.

    ``placement`` overrides the router's decision (benchmarks pin
    adversarial placements with it)."""
    if placement is None:
        placement = place(node, apps, router)
    coord = build_node(system, node, apps, list(placement), horizon=horizon,
                       seed=seed, lithos_config=lithos_config,
                       node_config=node_config, engine=engine,
                       collect_records=collect_records, faults=faults)
    results = coord.run()
    return NodeResult(node, router, list(placement), results,
                      coord.policies, coordinator=coord)
