"""Node layer: placement/routing of tenants across a multi-device node.

The paper's control plane manages one GPU; production serving runs fleets.
This layer generalizes the timing plane to a :class:`NodeSpec` of N devices:
each device runs its *own* policy instance (own SliceMap, quotas, predictor,
governor — no hidden cross-device state), and a router decides which device
each tenant's launch queue is pinned to.  Placement is per-client, not
per-job: a client's stream lives on one device for the simulation, matching
how serving frameworks pin model replicas (cross-device migration is the
elastic follow-on in the ROADMAP).

Router policies:

* ``round_robin``   — arrival-order striping; the no-information baseline.
* ``least_loaded``  — greedy bin-packing of estimated demand (service
  seconds/second from the cost model; closed-loop trainers count as a full
  device since they soak whatever they are given), largest first, onto the
  device with the lowest capacity-normalized load.
* ``quota_aware``   — place by guarantee headroom: HP tenants go where their
  quota still fits un-oversubscribed (largest quota first); BE tenants are
  spread by count (they run on stolen capacity, so one per device beats two
  on one).
* ``affinity``      — tenants sharing a model architecture co-locate
  (predictor/right-sizer state is per-(queue, ordinal): co-located replicas
  of one model warm the same operating regime), groups balanced by load.

Client ids are node-global (the original app order), so a tenant keeps the
same workload random stream under every placement — router comparisons see
identical arrivals, not resampled ones.
"""
from __future__ import annotations

from repro.core.simulator import SimResult, Simulator
from repro.core.types import NodeSpec, Priority
from repro.core.workloads import AppSpec, mean_demand

ROUTERS = ("round_robin", "least_loaded", "quota_aware", "affinity")


_demand_cache: dict[tuple, float] = {}


def demand_estimate(app: AppSpec, device) -> float:
    """Expected device-utilization fraction of one tenant (cost-model based,
    the same calibration the benchmarks use).  Load-based routers price
    demand on ``devices[0]`` and normalize loads by each device's capacity
    (`_argmin_load`), which is exact for homogeneous nodes and proportional
    for heterogeneous ones.  Memoized: mean_demand samples whole job traces
    through the cost model and is invariant per (workload, device)."""
    if app.kind == "train" or app.rps <= 0:
        return 1.0                       # closed loop: soaks a device
    key = (app.name, app.cfg.name, app.kind, app.batch, app.fusion,
           tuple(app.prompt_mix), app.decode_tokens, app.seed, app.rps,
           device)            # DeviceSpec is frozen: full profile, not just
                              # n_slices (cost model prices flops/bw too)
    if key not in _demand_cache:
        _demand_cache[key] = min(1.0, app.rps * mean_demand(app, device))
    return _demand_cache[key]


def _argmin_load(loads: list[float], node: NodeSpec) -> int:
    """Device with the lowest capacity-normalized load (ties: lowest id)."""
    base = node.devices[0].n_slices
    return min(range(node.n_devices),
               key=lambda d: (loads[d] * base / node.devices[d].n_slices, d))


def _effective_quota(app: AppSpec, node: NodeSpec, n_hp: int, d: int = 0,
                     headroom: int = None) -> int:
    """A-priori estimate of the guarantee ``app`` would need on device ``d``.

    Explicit quotas are exact: ``quotas_from_apps`` reserves them first,
    clamped to the device.  Derived HP shares depend on the final
    co-placement (they split whatever the explicit reservations leave), so
    the router estimates them from the device's *unreserved headroom* at
    decision time, divided by the node-wide HP count — conservative, and it
    tracks the reserve-explicit-first structure of ``quotas_from_apps``
    without duplicating its arithmetic against a fixed capacity."""
    dev = node.devices[d]
    if app.quota_slices > 0:
        return min(app.quota_slices, dev.n_slices)
    if app.priority == Priority.HIGH:
        cap = dev.n_slices if headroom is None else max(0, headroom)
        return cap // max(1, n_hp)
    return 0


def place(node: NodeSpec, apps: list[AppSpec],
          router: str = "least_loaded") -> list[int]:
    """Return the device index for each app.  Deterministic."""
    if router not in ROUTERS:
        raise ValueError(f"unknown router {router!r} (choose from {ROUTERS})")
    n = node.n_devices
    if n == 1:
        return [0] * len(apps)
    if router == "round_robin":
        return [i % n for i in range(len(apps))]

    placement = [0] * len(apps)
    if router == "least_loaded":
        demands = [demand_estimate(a, node.devices[0]) for a in apps]
        loads = [0.0] * n
        for i in sorted(range(len(apps)), key=lambda i: (-demands[i], i)):
            d = _argmin_load(loads, node)
            placement[i] = d
            loads[d] += demands[i]
        return placement

    if router == "quota_aware":
        n_hp = sum(1 for a in apps if a.priority == Priority.HIGH)
        # quota demand is sized per target device (devices may differ),
        # derived shares against the headroom left after reservations
        headroom = [dev.n_slices for dev in node.devices]
        quota_on = lambda i, d: _effective_quota(apps[i], node, n_hp, d,
                                                 headroom=headroom[d])
        be_count = [0] * n
        hp_order = sorted((i for i, a in enumerate(apps)
                           if a.priority == Priority.HIGH),
                          key=lambda i: (-max(_effective_quota(
                              apps[i], node, n_hp, d) for d in range(n)), i))
        for i in hp_order:
            # device where the guarantee still fits; else most headroom
            fits = [d for d in range(n) if headroom[d] >= quota_on(i, d)]
            cands = fits or range(n)
            d = min(cands, key=lambda d: (-headroom[d], d))
            placement[i] = d
            headroom[d] -= quota_on(i, d)
        for i, a in enumerate(apps):
            if a.priority == Priority.HIGH:
                continue
            d = min(range(n), key=lambda d: (be_count[d], -headroom[d], d))
            placement[i] = d
            be_count[d] += 1
        return placement

    if router == "affinity":
        groups: dict[str, list[int]] = {}
        for i, a in enumerate(apps):
            groups.setdefault(a.cfg.name, []).append(i)
        demands = [demand_estimate(a, node.devices[0]) for a in apps]
        gload = {g: sum(demands[i] for i in ids) for g, ids in groups.items()}
        loads = [0.0] * n
        for g in sorted(groups, key=lambda g: (-gload[g], g)):
            d = _argmin_load(loads, node)
            for i in groups[g]:
                placement[i] = d
            loads[d] += gload[g]
        return placement

    raise AssertionError(f"unhandled router {router!r}")  # ROUTERS is closed


class NodeResult:
    """Aggregated result of one node run: per-device :class:`SimResult`s
    plus node-level metrics with the same read surface as a SimResult
    (``client(name)``, ``clients``, ``energy``, ``utilization``,
    ``records``)."""

    def __init__(self, node: NodeSpec, router: str, placement: list[int],
                 results: list[SimResult], policies: list):
        self.node = node
        self.router = router
        self.placement = placement
        self.per_device = results
        self.policies = policies
        self.policy = policies[0] if policies else None
        self.horizon = results[0].horizon
        self.policy_name = results[0].policy_name
        self.energy = sum(r.energy for r in results)
        self.busy_slice_seconds = sum(r.busy_slice_seconds for r in results)
        self.records = [rec for r in results for rec in r.records]
        self.clients = sorted((c for r in results for c in r.clients),
                              key=lambda c: c.cid)

    @property
    def utilization(self) -> float:
        return self.busy_slice_seconds / (self.horizon
                                          * self.node.total_slices)

    def client(self, name: str):
        return next(c for c in self.clients if c.name == name)

    def device_of(self, name: str) -> int:
        """Device index a named client was placed on."""
        cid = self.client(name).cid
        return self.placement[cid]


def evaluate_node(system: str, node: NodeSpec, apps: list[AppSpec], *,
                  horizon: float = 30.0, seed: int = 0,
                  lithos_config=None, router: str = "least_loaded"
                  ) -> NodeResult:
    """Route ``apps`` across the node, run one simulator + policy instance
    per device, aggregate.  Devices are independent under static placement,
    so per-device runs share nothing but the seed."""
    from repro.core.lithos import make_policy

    placement = place(node, apps, router)
    results: list[SimResult] = []
    policies = []
    for d, dev in enumerate(node.devices):
        idx = [i for i, p in enumerate(placement) if p == d]
        dev_apps = [apps[i] for i in idx]
        policy = make_policy(system, dev, dev_apps,
                             lithos_config=lithos_config, cids=idx)
        sim = Simulator(dev, dev_apps, policy, horizon=horizon, seed=seed,
                        cids=idx)
        results.append(sim.run())
        policies.append(policy)
    return NodeResult(node, router, placement, results, policies)
