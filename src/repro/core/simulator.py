"""Calibrated discrete-event device simulator (the timing plane).

Executes :class:`KernelTask`s on a :class:`DeviceSpec` under a pluggable
scheduling :class:`Policy`.  Ground-truth latencies come from the roofline
cost model; the OS components observe only :class:`CompletionRecord`s — they
never see flops/bytes — so predictor / right-sizer / DVFS learn online
exactly as on real hardware.

Execution model (fluid DES): an in-flight kernel has a fixed *overhead*
phase (launch/tail, wall time) followed by a *divisible* phase that drains at
a rate set by its current slice allocation and the device frequency.  The
policy's ``allocations()`` is re-evaluated at every event, so policies may
space-partition (LithOS, MIG), processor-share (MPS), prioritize (Priority),
gate (REEF/TGS/Orion), or time-slice.  Preemption support: ``kill()``
requeues a kernel with all progress lost (REEF reset semantics).

Energy: device power P = static + n*idle + busy*dyn*(f/fmax)^3 integrated
between events.
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.costmodel import CostModel, WorkPhases
from repro.core.queues import Client
from repro.core.types import (CompletionRecord, DeviceSpec, KernelTask,
                              Priority)
from repro.core.workloads import AppSpec


@dataclass
class ExecKernel:
    """An in-flight kernel/atom."""

    task: KernelTask
    client: Client
    phases: WorkPhases
    t_submit: float
    t_start: float
    overhead_left: float
    div_left: float = 1.0               # fraction of divisible phase left
    slices: int = 0
    slice_set: tuple[int, ...] = ()
    stolen: bool = False
    gen: int = 0                        # event-invalidation counter

    interference: float = 1.0           # speed factor (set by simulator)

    def speed(self, f: float, occupancy: int) -> float:
        """d(div_left)/dt at allocation ``slices`` and rel. frequency f."""
        if self.slices <= 0:
            return 0.0
        t_div = self.phases.divisible_time(self.slices, f, occupancy)
        return (self.interference / t_div) if t_div > 0 else float("inf")

    def eta(self, f: float, occupancy: int) -> float:
        if self.slices <= 0:
            return float("inf")
        sp = self.speed(f, occupancy)
        div_t = self.div_left / sp if sp != float("inf") else 0.0
        return self.overhead_left + div_t


class Policy:
    """Scheduling policy interface (subclassed by LithOS and baselines).

    Slice allocations follow GPU block semantics: granted at dispatch, may
    GROW mid-flight (remaining blocks spread onto freed slices) but never
    shrink — running thread blocks are non-preemptible.  Policies that model
    hardware context switching (TimeSlice) set ``allow_shrink``; REEF-style
    reset preemption uses ``Simulator.kill`` instead.
    """

    name = "base"
    tick_interval: float = 0.0          # >0: periodic on_tick callbacks
    allow_shrink: bool = False
    # Cross-tenant interference when kernels from multiple clients are
    # co-resident (L2/HBM/scheduler contention — the cost of MPS-style
    # stacking the paper's §2.2 describes).  Spatially isolating policies
    # (LithOS, MIG) keep 0; MPS/Priority/TGS pay it.
    interference_penalty: float = 0.0
    # Cross-device migration protocol (node-level lending).  A policy that
    # opts in implements hold/drain/export/import below; the coordinator
    # never migrates between policies that do not.
    supports_migration: bool = False

    def attach(self, sim: "Simulator"):
        self.sim = sim

    def step(self, now: float):
        """Called after every event: examine queues, dispatch kernels."""
        raise NotImplementedError

    def allocations(self, now: float) -> dict[int, int]:
        """kid -> slices for all in-flight kernels, re-evaluated per event.
        Default: keep each kernel's current allocation."""
        return {ek.task.kid: ek.slices for ek in self.sim.in_flight.values()}

    def alloc_changes(self, now: float) -> Optional[dict[int, int]]:
        """Allocation *deltas* for the vectorized engine (engine_vec).

        Return a (possibly empty) dict of kid -> slices covering every
        in-flight kernel whose allocation MAY differ from its current one;
        kernels absent from the dict are promised unchanged, so the engine
        can skip the per-kernel compare-and-reschedule scan entirely when
        the dict is empty.  Return None (the default) to make the engine
        fall back to a full ``allocations()`` comparison — always correct,
        never fast.  The reference engine never calls this; results must be
        identical either way (the parity suite runs both)."""
        return None

    def on_complete(self, ek: ExecKernel, rec: CompletionRecord):
        pass

    def on_tick(self, now: float):
        pass

    def on_fault(self, f, now: float):
        """React to an injected :class:`~repro.core.types.FaultEvent` on
        this device (simulator callback; never called on fault-free runs).

        ``device_dead`` contract: when this returns, nothing may remain in
        flight — the generic implementation REEF-kills every in-flight
        kernel and puts its task back at the owning client's queue head,
        so the tier above can evacuate intact launch queues.
        ``slice_retired`` is a no-op here (policies without slice
        ownership see the shrink through ``sim.free_slices``);
        ownership-aware policies override (LithOSScheduler retires the
        slice in its SliceMap and shrinks the owner's quota)."""
        if f.kind != "device_dead":
            return
        for kid in list(self.sim.in_flight):
            ek = self.sim.in_flight[kid]
            task = self.sim.kill(kid)
            if task is not None and not task.is_atom:
                ek.client.requeue(task)

    # -- migration protocol (node-level lending; no-ops by default) ---------

    def hold_client(self, cid: int):
        """Stop planning new kernels for ``cid`` (drain toward a kernel
        boundary).  In-flight work still completes."""

    def release_hold(self, cid: int):
        """Resume dispatching for ``cid`` (migration landed or aborted)."""

    def client_drained(self, cid: int) -> bool:
        """True when ``cid`` sits at a kernel boundary: nothing in flight
        and nothing planned — safe to move its launch queue."""
        c = self.sim.client_by_id.get(cid)
        return c is not None and c.outstanding == 0

    def export_client_state(self, cid: int) -> dict:
        """Forget a migrating client; return warm state for the target
        policy (predictor observations etc.).

        Contract: for any policy P with learned per-client state, running
        ``target.import_client_state(cid, prio, src.export_client_state(cid))``
        must reproduce that state *exactly* on the target — same predictor
        nodes, same quota — and remove it from the source (no double
        residency).  The base class carries no per-client state, so it
        returns ``{}`` and ``import_client_state`` is a no-op; a policy that
        learns per-client state MUST override both sides or migration
        silently discards its warm state (test_policy_state asserts the
        round-trip for LithOSScheduler)."""
        return {}

    def import_client_state(self, cid: int, priority, state: dict):
        """Admit a migrated client, warming from the source's state."""


class Simulator:
    #: engine discriminator — VecSimulator (engine_vec) sets True; policies
    #: branch on ``getattr(sim, "vec", False)`` to pick their fast paths
    vec = False

    def __init__(self, device: DeviceSpec, apps: list[AppSpec],
                 policy: Policy, *, horizon: float = 30.0, seed: int = 0,
                 cids: Optional[list[int]] = None,
                 collect_records: bool = True,
                 faults=()):
        """``cids`` gives each app an explicit client id (default 0..n-1).
        The node layer passes node-global ids so a tenant keeps the same id
        (and hence the same workload random stream) under any placement.

        ``collect_records=False`` is the lean-memory mode for throughput
        benchmarks on million-request traces: per-kernel CompletionRecords
        are not retained and completed jobs drop their batch/task objects.
        Timing, energy and client metrics are unaffected; it applies
        identically to both engines so comparisons stay fair."""
        self.device = device
        self.cost = CostModel(device)
        self.policy = policy
        self.horizon = horizon
        self.now = 0.0
        self.freq = 1.0
        self._pending_freq: Optional[float] = None
        self.in_flight: dict[int, ExecKernel] = {}
        self._heap: list[tuple[float, int, str, object]] = []
        self._counter = itertools.count()
        self.energy = 0.0
        self.busy_slice_seconds = 0.0
        self.events = 0             # events processed (throughput metric)
        self.records: list[CompletionRecord] = []
        self.collect_records = collect_records
        self.done = False
        # Injected hardware faults (FaultEvents targeting this device).
        # Empty on fault-free runs: zero extra heap events, so behavior is
        # bit-for-bit identical to a build without fault support.
        self._fault_events = tuple(faults or ())
        self.dead = False               # device_dead fired
        self.n_retired = 0              # slices lost to slice_retired
        self.fault_log: list = []       # (t, FaultEvent) as applied
        # arrival-stream generation per client: bumped on detach so stale
        # arrival events left in the heap are ignored if the client returns
        self._arr_gen: dict[int, int] = {}
        if cids is None:
            cids = list(range(len(apps)))
        assert len(cids) == len(apps) and len(set(cids)) == len(cids)
        self.clients = [Client(cid, a, horizon, seed=seed)
                        for cid, a in zip(cids, apps)]
        # Per-simulator kernel-id stream: kid assignment depends only on
        # this simulator's own event order, so interleaving several
        # simulators (node/cluster tiers) is unobservable in the records —
        # sequential and interleaved runs stay bit-for-bit identical.
        self.kernel_ids = itertools.count()
        for c in self.clients:
            c.kids = self.kernel_ids
        if not collect_records:
            for c in self.clients:
                c._drop_batches = True
        self.client_by_id = {c.cid: c for c in self.clients}
        policy.attach(self)

    # -- event plumbing ---------------------------------------------------------

    def _push(self, t: float, kind: str, payload: object = None):
        heapq.heappush(self._heap, (t, next(self._counter), kind, payload))

    def set_frequency(self, f: float):
        """Request a frequency switch (takes f_switch_latency)."""
        if abs(f - self.freq) < 1e-9 or self._pending_freq is not None:
            return
        self._pending_freq = f
        self._push(self.now + self.device.f_switch_latency, "fswitch", f)

    # -- dispatch interface (called by policies) ---------------------------------

    def start_kernel(self, client: Client, task: KernelTask, slices: int,
                     *, slice_set: tuple[int, ...] = (),
                     stolen: bool = False, t_submit: Optional[float] = None
                     ) -> ExecKernel:
        phases = self.cost.phases(task.work)
        ek = ExecKernel(task=task, client=client, phases=phases,
                        t_submit=self.now if t_submit is None else t_submit,
                        t_start=self.now,
                        overhead_left=phases.overhead,
                        slices=max(0, slices), slice_set=slice_set,
                        stolen=stolen)
        self.in_flight[task.kid] = ek
        self._schedule_completion(ek)
        return ek

    def kill(self, kid: int) -> Optional[KernelTask]:
        """REEF-style reset: drop an in-flight kernel, losing progress."""
        ek = self.in_flight.pop(kid, None)
        if ek is None:
            return None
        ek.gen += 1
        return ek.task

    def _schedule_completion(self, ek: ExecKernel):
        ek.gen += 1
        eta = ek.eta(self.freq, self.device.occupancy)
        if eta != float("inf"):
            self._push(self.now + eta, "complete", (ek.task.kid, ek.gen))

    # -- state advance ------------------------------------------------------------

    def _advance(self, t_new: float):
        dt = t_new - self.now
        if dt <= 0:
            self.now = max(self.now, t_new)
            return
        busy = min(sum(min(ek.slices, ek.phases.max_useful_slices)
                       for ek in self.in_flight.values()),
                   self.device.n_slices)
        self.energy += dt * self.device.power(busy, self.freq)
        self.busy_slice_seconds += dt * busy
        for ek in self.in_flight.values():
            used = dt
            if ek.overhead_left > 0:
                o = min(ek.overhead_left, used)
                ek.overhead_left -= o
                used -= o
            if used > 0 and ek.div_left > 0:
                ek.div_left = max(
                    0.0, ek.div_left - used * ek.speed(self.freq,
                                                       self.device.occupancy))
            # capacity accounting: slices HELD (denied to other tenants),
            # not just usefully busy — right-sizing savings live here
            ek.client.slice_seconds += dt * ek.slices
        self.now = t_new

    def _apply_allocations(self):
        alloc = self.policy.allocations(self.now)
        # interference: multiple tenants co-resident slow everyone down
        pen = self.policy.interference_penalty
        n_tenants = len({ek.client.cid for ek in self.in_flight.values()})
        factor = max(0.3, 1.0 - pen * (n_tenants - 1)) if pen else 1.0
        changed = []
        for kid, ek in self.in_flight.items():
            s = max(0, alloc.get(kid, ek.slices))
            if not self.policy.allow_shrink:
                s = max(s, ek.slices)      # blocks are non-preemptible
            if s != ek.slices or abs(factor - ek.interference) > 1e-9:
                ek.slices = s
                ek.interference = factor
                changed.append(ek)
        for ek in changed:
            self._schedule_completion(ek)
        return changed

    def held_slices(self) -> int:
        return sum(ek.slices for ek in self.in_flight.values())

    def free_slices(self) -> int:
        return max(0, self.device.n_slices - self.n_retired
                   - self.held_slices())

    # -- fault injection ---------------------------------------------------------

    def _apply_fault(self, f) -> bool:
        """Apply one injected FaultEvent.  Returns True when the fault
        permanently kills the device (the caller ends the event stream)."""
        self.fault_log.append((self.now, f))
        if f.kind == "transient_stall":
            # SXid-style hiccup: every in-flight kernel stalls for
            # ``duration`` wall seconds (modeled as extra overhead phase)
            for ek in self.in_flight.values():
                ek.overhead_left += f.duration
                self._schedule_completion(ek)
            return False
        if f.kind == "slice_retired":
            self.n_retired += 1
            self.policy.on_fault(f, self.now)
            return False
        # device_dead: the policy resets in-flight work back onto the
        # clients' launch queues (REEF kill semantics) so the tier above
        # can evacuate intact queues; then the device stops for good.
        self.policy.on_fault(f, self.now)
        assert not self.in_flight, \
            "policy.on_fault(device_dead) must clear all in-flight work"
        self.dead = True
        return True

    def _complete(self, ek: ExecKernel):
        del self.in_flight[ek.task.kid]
        rec = CompletionRecord(task=ek.task, t_submit=ek.t_submit,
                               t_start=ek.t_start, t_end=self.now,
                               slices=ek.slices, freq=self.freq)
        if self.collect_records:
            self.records.append(rec)
        self.policy.on_complete(ek, rec)

    # -- client migration (node-level lending protocol) --------------------------

    def detach_client(self, cid: int) -> "Client":
        """Remove a *drained* client so its launch queue can move to another
        device.  Future arrival events it left in the heap are invalidated
        via the per-client arrival generation."""
        c = self.client_by_id.pop(cid)
        assert c.outstanding == 0, "detach requires a drained launch queue"
        self.clients.remove(c)
        self._arr_gen[cid] = self._arr_gen.get(cid, 0) + 1
        return c

    def admit_client(self, client: "Client", after: float):
        """Add a migrated-in client immediately (it appears in this
        simulator's result even if the horizon ends before it runs).  The
        caller gates dispatch via the policy's hold until the migration
        cost has been paid (:meth:`schedule_release`).

        ``after`` is the migration instant on the *source* clock: arrivals
        at or before it already fired there (their jobs travel in the
        client's pending queue), so only strictly later ones are re-seeded
        here — this simulator's own clock may still lag behind."""
        assert client.cid not in self.client_by_id
        # Re-key the client into this simulator's kernel-id stream: its
        # undispatched queue still carries source-simulator kids, which
        # could collide with ids already dealt here (in_flight and the
        # SliceMap are kid-keyed).  Dispatched tasks are left alone —
        # their completion records live in the source simulator.
        client.kids = self.kernel_ids
        for task in client.undispatched_tasks():
            task.kid = next(self.kernel_ids)
        self.clients.append(client)
        self.client_by_id[client.cid] = client
        gen = self._arr_gen.get(client.cid, 0)
        for t in client.arrivals():          # open-loop: future arrivals
            if t > after:
                self._push(t, "arrival", (client.cid, gen))

    def schedule_release(self, cid: int, at: float):
        """Schedule the end of a migrated client's hold (migration cost)."""
        self._push(max(at, self.now), "unhold", cid)

    # -- main loop ------------------------------------------------------------------

    def start(self):
        """Seed the event heap; call once before stepping."""
        for c in self.clients:
            for t in c.arrivals():
                self._push(t, "arrival", (c.cid, 0))
            if c.closed_loop:
                self._push(0.0, "arrival", (c.cid, 0))
        if self.policy.tick_interval > 0:
            self._push(self.policy.tick_interval, "tick", None)
        self._push(self.horizon, "end", None)
        for f in self._fault_events:
            self._push(f.t, "fault", f)

    def peek_time(self) -> Optional[float]:
        """Timestamp of the next pending event (None when finished)."""
        if self.done or not self._heap:
            return None
        return self._heap[0][0]

    def step_event(self) -> bool:
        """Process exactly one event (one iteration of the historical run
        loop).  Returns False once the run is over."""
        if self.done or not self._heap:
            self.done = True
            return False
        t, _, kind, payload = heapq.heappop(self._heap)
        self.events += 1
        if t > self.horizon and kind != "end":
            return True                     # post-horizon stragglers: skip
        self._advance(t)
        if kind == "end":
            self.done = True
            return False
        if kind == "arrival":
            cid, gen = payload
            c = self.client_by_id.get(cid)
            if c is None or gen != self._arr_gen.get(cid, 0):
                return True                 # migrated away: stale arrival
            c.on_arrival(self.now)
        elif kind == "complete":
            kid, gen = payload
            ek = self.in_flight.get(kid)
            if ek is None or ek.gen != gen:
                return True
            if ek.overhead_left > 1e-12 or ek.div_left > 1e-9:
                self._schedule_completion(ek)   # stale estimate; refresh
                return True
            self._complete(ek)
        elif kind == "fswitch":
            self.freq = payload
            self._pending_freq = None
            for ek in self.in_flight.values():
                self._schedule_completion(ek)
        elif kind == "tick":
            self.policy.on_tick(self.now)
            self._push(self.now + self.policy.tick_interval, "tick", None)
        elif kind == "unhold":
            self.policy.release_hold(payload)
        elif kind == "fault":
            if self._apply_fault(payload):
                self.done = True        # device dead: event stream ends
                return False
        # policy reacts to the new state (apply first so context
        # switches / grows take effect before dispatch decisions)
        self._apply_allocations()
        self.policy.step(self.now)
        for c in self.clients:
            c.start_next_job(self.now)
        self.policy.step(self.now)
        self._apply_allocations()
        return True

    def run(self) -> "SimResult":
        self.start()
        while self.step_event():
            pass
        return SimResult(self)


@dataclass
class ClientMetrics:
    name: str
    priority: Priority
    n_completed: int
    throughput: float
    latencies: list[float]
    slice_seconds: float
    arrivals: list[float] = None
    horizon: float = 0.0
    cid: int = -1                       # node-global client id
    kernels_per_job: float = 0.0        # mean kernels of the jobs issued
    # Continuous-batching tenants: request-level latencies (arrival ->
    # last token; latencies above are per-iteration TBT there) and the
    # peak KV-cache footprint the tenant reached.
    req_latencies: list[float] = None
    kv_peak_bytes: float = 0.0

    def _lat(self, warmup: float = 0.0) -> list[float]:
        if warmup <= 0 or not self.arrivals:
            return self.latencies
        t0 = warmup * self.horizon
        out = [l for a, l in zip(self.arrivals, self.latencies) if a >= t0]
        return out or self.latencies

    def p(self, q: float, warmup: float = 0.0) -> float:
        lat = self._lat(warmup)
        if not lat:
            return float("nan")
        return float(np.percentile(lat, q))

    @property
    def p50(self):
        return self.p(50)

    @property
    def p95(self):
        return self.p(95)

    @property
    def p99(self):
        return self.p(99)

    def slo_attainment(self, slo: float) -> float:
        if not self.latencies or slo <= 0:
            return float("nan")
        return float(np.mean([l <= slo for l in self.latencies]))

    def goodput(self, slo: float, horizon: float) -> float:
        if slo <= 0:
            return self.throughput
        return sum(l <= slo for l in self.latencies) / horizon


class SimResult:
    def __init__(self, sim: Simulator):
        self.device = sim.device
        self.horizon = sim.horizon
        self.energy = sim.energy
        self.busy_slice_seconds = sim.busy_slice_seconds
        self.records = sim.records
        self.policy_name = sim.policy.name
        self.clients = [ClientMetrics(
            name=c.spec.name, priority=c.spec.priority,
            n_completed=len(c.completed),
            throughput=c.throughput(sim.horizon),
            latencies=c.latencies(), slice_seconds=c.slice_seconds,
            arrivals=[j.arrival for j in c.completed], horizon=sim.horizon,
            cid=c.cid,
            kernels_per_job=(sum(c.job_kernel_counts)
                             / len(c.job_kernel_counts)
                             if c.job_kernel_counts else 0.0),
            req_latencies=c.req_latencies(),
            kv_peak_bytes=c.kv_peak_bytes())
            for c in sim.clients]

    @property
    def utilization(self) -> float:
        return self.busy_slice_seconds / (self.horizon * self.device.n_slices)

    def client(self, name: str) -> ClientMetrics:
        return next(c for c in self.clients if c.name == name)


ENGINES = ("ref", "vec")


def make_simulator(device: DeviceSpec, apps: list[AppSpec], policy: Policy,
                   *, engine: str = "ref", **kw) -> Simulator:
    """Engine-selecting constructor.  ``ref`` is the scalar oracle defined
    in this module; ``vec`` is the vectorized core (engine_vec) with a
    bit-for-bit parity contract against it."""
    if engine == "vec":
        from repro.core.engine_vec import VecSimulator
        return VecSimulator(device, apps, policy, **kw)
    if engine == "ref":
        return Simulator(device, apps, policy, **kw)
    raise ValueError(f"unknown engine {engine!r} (choose from {ENGINES})")


def run_sim(device: DeviceSpec, apps: list[AppSpec], policy: Policy, *,
            horizon: float = 30.0, seed: int = 0,
            engine: str = "ref") -> SimResult:
    return make_simulator(device, apps, policy, engine=engine,
                          horizon=horizon, seed=seed).run()
