"""SliceMap — the first-class slice-resource subsystem (§4.2–4.3).

The TPC Scheduler's ground truth about the device's core-slices lives here:

* **Ownership** (§4.2): each slice is owned by one client (its quota) or by
  the shared pool.  Ownership is static for a simulation; re-partitioning is
  a future elastic-migration concern.
* **Holding**: a slice is *held* by at most one in-flight kernel/atom (kid).
  Acquire/release keep incremental idle free-lists per owner plus a pool
  free-list, so free-slice queries cost O(idle slices of the queried owners)
  instead of the O(n_slices) full scans the scheduler used to run on every
  event.
* **Lending / steal ledger** (§4.3 TPC Stealing): every acquisition of a
  slice owned by *another* client opens a :class:`LendRecord`; release closes
  it.  The ledger is the audit trail for conservation tests and the precise
  per-slice-second accounting (``lent_slice_seconds``).  The paper-facing
  ``stolen_slice_seconds`` metric keeps its historical semantics (kernel
  latency × total slices for kernels that dispatched on stolen slices) and is
  credited by the scheduler via :meth:`note_stolen_completion`.
* **Per-slice timers**: ``busy_until`` records the predicted completion of
  the holding atom (from the §4.7 predictor) — when a borrowed slice is due
  back.  Forward-looking state: no scheduling decision reads the timers yet
  (the seed scheduler kept them write-only too); cross-device stealing and
  lend-deadline policies (ROADMAP) are the intended consumers.
* **Conservation invariants**: :meth:`check` asserts, at any instant, that
  owned-idle + pool-idle + held partitions the device exactly and that no
  slice is held by two kernels.

Policies own a SliceMap instance; the simulator never sees it.  MIG/Limits
use the same subsystem with stealing disabled structurally (they only ever
acquire from their own partition).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Optional, Sequence

from repro.core.types import Quota


@dataclass
class LendRecord:
    """One slice lent across an ownership boundary for one kernel/atom."""

    slice_id: int
    owner: int                      # lending client
    borrower: int                   # borrowing client
    kid: int                        # holding kernel/atom
    t_start: float
    t_end: Optional[float] = None   # None while the lend is open

    @property
    def open(self) -> bool:
        return self.t_end is None

    @property
    def duration(self) -> float:
        return 0.0 if self.t_end is None else self.t_end - self.t_start


class SliceMap:
    """Slice ownership + holding state with incremental free-lists."""

    def __init__(self, n_slices: int):
        self.n_slices = n_slices
        self.owner: list[Optional[int]] = [None] * n_slices
        self.holder: list[Optional[int]] = [None] * n_slices   # holding kid
        self.busy_until: list[float] = [0.0] * n_slices
        # incremental free-lists (idle == not held)
        self._idle_own: dict[int, set[int]] = {}
        self._idle_pool: set[int] = set(range(n_slices))
        self._held_by_kid: dict[int, list[int]] = {}
        # ECC-retired slices: permanently out of every free-list (fault
        # injection).  A held slice retires lazily at its release — blocks
        # are non-preemptible, so the in-flight kernel finishes first.
        self.retired: set[int] = set()
        self._pending_retire: set[int] = set()
        # steal/lend accounting
        self.ledger: list[LendRecord] = []
        self._open_lends: dict[tuple[int, int], LendRecord] = {}  # (kid, sid)
        self.lent_slice_seconds = 0.0       # precise, per-slice, from ledger
        self.stolen_slice_seconds = 0.0     # legacy kernel-level metric

    # -- construction --------------------------------------------------------

    @classmethod
    def from_quotas(cls, n_slices: int, quotas: dict[int, Quota]) -> "SliceMap":
        """Assign each client its quota slices in ascending cid order (the
        historical LithOSScheduler layout), remainder to the pool."""
        sm = cls(n_slices)
        nxt = 0
        for cid, q in sorted(quotas.items()):
            for _ in range(q.slices):
                if nxt < n_slices:
                    sm.assign_owner(nxt, cid)
                    nxt += 1
        return sm

    @classmethod
    def from_partitions(cls, n_slices: int,
                        partitions: dict[int, int]) -> "SliceMap":
        """MIG-style: contiguous partitions in ascending cid order; slices
        beyond the partitioned range stay pool-owned but MIG policies never
        touch them (the stranded capacity the paper quantifies)."""
        sm = cls(n_slices)
        nxt = 0
        for cid, n in sorted(partitions.items()):
            for _ in range(n):
                if nxt < n_slices:
                    sm.assign_owner(nxt, cid)
                    nxt += 1
        return sm

    def assign_owner(self, sid: int, cid: int):
        assert self.holder[sid] is None, "cannot re-own a held slice"
        old = self.owner[sid]
        if old is None:
            self._idle_pool.discard(sid)
        else:
            self._idle_own[old].discard(sid)
        self.owner[sid] = cid
        self._idle_own.setdefault(cid, set()).add(sid)

    def disown(self, sid: int):
        """Return an idle owned slice to the shared pool — the elastic half
        of ownership: the control plane grants a quota at admission
        (:meth:`assign_owner` on pool slices) and returns it when the
        tenant exits.  A held slice cannot be disowned (blocks are
        non-preemptible); callers retry once the holder releases."""
        assert self.holder[sid] is None, "cannot disown a held slice"
        old = self.owner[sid]
        if old is None:
            return
        self.owner[sid] = None
        s = self._idle_own[old]
        s.discard(sid)
        if not s and self.owned_by(old) == 0:
            del self._idle_own[old]
        self._idle_pool.add(sid)

    def retire(self, sid: int) -> bool:
        """Permanently remove a slice from service (ECC-style fault).

        An idle slice retires immediately; a held one is marked and
        retires when its holding kernel releases it (non-preemptible
        blocks finish first).  Returns True once the slice is out of
        service, False while the retire is pending on a release."""
        if sid in self.retired:
            return True
        if self.holder[sid] is not None:
            self._pending_retire.add(sid)
            return False
        self._do_retire(sid)
        return True

    def _do_retire(self, sid: int):
        o = self.owner[sid]
        if o is None:
            self._idle_pool.discard(sid)
        else:
            s = self._idle_own[o]
            s.discard(sid)
            self.owner[sid] = None
            if not s and self.owned_by(o) == 0:
                del self._idle_own[o]
        self.owner[sid] = None
        self.retired.add(sid)

    # -- queries (incremental free-lists) ------------------------------------

    def owners(self) -> list[int]:
        """Clients owning at least one slice, ascending."""
        return sorted(self._idle_own.keys())

    def owned_by(self, cid: int) -> int:
        return sum(1 for o in self.owner if o == cid)

    def idle_owned(self, cid: int) -> list[int]:
        return sorted(self._idle_own.get(cid, ()))

    def n_own_idle(self, cid: int) -> int:
        return len(self._idle_own.get(cid, ()))

    def idle_pool(self) -> list[int]:
        return sorted(self._idle_pool)

    def total_idle(self) -> int:
        """Idle slices of any kind (owned + pool)."""
        return len(self._idle_pool) + sum(
            len(s) for s in self._idle_own.values())

    def n_owned_idle_total(self) -> int:
        """Idle slices with an owner (pool excluded)."""
        return sum(len(s) for s in self._idle_own.values())

    def idle_owners(self) -> list[int]:
        """Owners with at least one idle slice, ascending — exactly
        ``[o for o in owners() if n_own_idle(o) > 0]``."""
        return [o for o in sorted(self._idle_own) if self._idle_own[o]]

    def idle_stealable(self, borrower: int,
                       lenders: Iterable[int]) -> list[int]:
        """Idle slices owned by the given (willing) lenders, ascending —
        matching the historical whole-device-scan ordering."""
        out: set[int] = set()
        for o in lenders:
            if o == borrower:
                continue
            out |= self._idle_own.get(o, set())
        return sorted(out)

    def free_for(self, borrower: int, *, lenders: Iterable[int] = (),
                 include_pool: bool = True) -> list[int]:
        """Slice ids the borrower may use right now: its own idle slices,
        then the idle pool, then idle slices of willing lenders — each group
        in ascending slice-id order (dispatch preference: own > pool >
        stolen, so steals are the last resort and return soonest)."""
        free = self.idle_owned(borrower)
        if include_pool:
            free += self.idle_pool()
        free += self.idle_stealable(borrower, lenders)
        return free

    def held_by(self, kid: int) -> tuple[int, ...]:
        return tuple(self._held_by_kid.get(kid, ()))

    # -- transitions ---------------------------------------------------------

    def acquire(self, slice_ids: Sequence[int], kid: int, borrower: int,
                now: float, eta: Optional[float] = None) -> bool:
        """Mark slices held by ``kid`` on behalf of ``borrower``.

        ``eta`` (predicted completion latency) sets the per-slice return
        timer; growth acquisitions pass ``eta=None`` and keep the timer
        monotone.  Returns True iff any acquired slice is *stolen* (owned by
        a different client — pool slices are free capacity, not steals).
        Opens a ledger record per stolen slice.
        """
        stolen = False
        for sid in slice_ids:
            assert self.holder[sid] is None, (sid, self.holder[sid], kid)
            o = self.owner[sid]
            self.holder[sid] = kid
            self.busy_until[sid] = (now + eta if eta is not None
                                    else max(self.busy_until[sid], now))
            if o is None:
                self._idle_pool.discard(sid)
            else:
                self._idle_own[o].discard(sid)
            self._held_by_kid.setdefault(kid, []).append(sid)
            if o is not None and o != borrower:
                stolen = True
                rec = LendRecord(sid, o, borrower, kid, now)
                self.ledger.append(rec)
                self._open_lends[(kid, sid)] = rec
        return stolen

    def release(self, kid: int, now: float) -> tuple[int, ...]:
        """Free every slice held by ``kid``; closes its lend records."""
        freed = self._held_by_kid.pop(kid, [])
        for sid in freed:
            assert self.holder[sid] == kid
            self.holder[sid] = None
            self.busy_until[sid] = now
            o = self.owner[sid]
            if o is None:
                self._idle_pool.add(sid)
            else:
                self._idle_own[o].add(sid)
            rec = self._open_lends.pop((kid, sid), None)
            if rec is not None:
                rec.t_end = now
                self.lent_slice_seconds += rec.duration
            if sid in self._pending_retire:
                self._pending_retire.discard(sid)
                self._do_retire(sid)
        return tuple(freed)

    def note_stolen_completion(self, latency: float, slices: int):
        """Credit the paper-facing steal metric (kernel latency × slices for
        kernels dispatched on stolen capacity — §7 accounting)."""
        self.stolen_slice_seconds += latency * slices

    # -- invariants ----------------------------------------------------------

    def counts(self) -> dict[str, int]:
        held = sum(len(v) for v in self._held_by_kid.values())
        owned_idle = sum(len(v) for v in self._idle_own.values())
        return {"owned_idle": owned_idle, "pool_idle": len(self._idle_pool),
                "held": held,
                "lent": sum(1 for r in self.ledger if r.open),
                "retired": len(self.retired)}

    def check(self):
        """Conservation: idle ∪ held ∪ retired partitions [0, n_slices); no
        slice is held twice; free-lists agree with the holder array; open
        ledger entries match currently-held stolen slices."""
        held: set[int] = set()
        for kid, ids in self._held_by_kid.items():
            for sid in ids:
                assert sid not in held, f"slice {sid} held twice"
                assert self.holder[sid] == kid, (sid, kid, self.holder[sid])
                held.add(sid)
        idle: set[int] = set()
        for cid, ids in self._idle_own.items():
            for sid in ids:
                assert self.owner[sid] == cid
                assert sid not in idle
                idle.add(sid)
        for sid in self._idle_pool:
            assert self.owner[sid] is None
            assert sid not in idle
            idle.add(sid)
        assert not (held & idle), held & idle
        for sid in self.retired:
            assert self.holder[sid] is None and self.owner[sid] is None, sid
            assert sid not in held and sid not in idle, sid
        assert self._pending_retire <= held, (self._pending_retire, held)
        assert len(held) + len(idle) + len(self.retired) == self.n_slices, (
            len(held), len(idle), len(self.retired), self.n_slices)
        for sid in idle:
            assert self.holder[sid] is None, sid
        open_lends = {(r.kid, r.slice_id) for r in self.ledger if r.open}
        assert open_lends == set(self._open_lends)
        for kid, sid in open_lends:
            assert self.holder[sid] == kid
            assert self.owner[sid] is not None
        closed = sum(r.duration for r in self.ledger if not r.open)
        assert abs(closed - self.lent_slice_seconds) < 1e-9
        return True


def _mask_bits(m: int) -> list[int]:
    """Set-bit indices of a mask, ascending."""
    out = []
    while m:
        b = m & -m
        out.append(b.bit_length() - 1)
        m ^= b
    return out


class VecSliceMap:
    """Bit-packed SliceMap for the vectorized engine (engine_vec).

    Same interface, ordering and accounting semantics as :class:`SliceMap`
    — free-lists are integer bitmasks (one bit per slice), so free-slice
    queries, acquire and release are word ops instead of set/sort churn
    (``SliceMap.acquire`` alone was ~360 µs/call in the reference profile).
    Differences, all invisible to scheduling decisions:

    * no per-lend :class:`LendRecord` objects — ``lent_slice_seconds`` is
      accumulated from per-slice open-lend start times in release order,
      which is exactly the order the reference ledger closes records in,
      so the float sum is bit-identical; ``ledger`` is not provided (the
      ledger-inspecting tests run the reference engine).
    * ``check()`` verifies the same partition/holder/open-lend invariants
      directly on the masks.

    Python bigints make this width-agnostic (n_slices > 64 still works).
    """

    def __init__(self, n_slices: int):
        self.n_slices = n_slices
        self.owner: list[Optional[int]] = [None] * n_slices
        self.holder: list[Optional[int]] = [None] * n_slices
        self.busy_until: list[float] = [0.0] * n_slices
        self._idle_own: dict[int, int] = {}          # cid -> idle mask
        self._own_mask: dict[int, int] = {}          # cid -> owned mask
        self._idle_owned_union: int = 0              # union of _idle_own
        self._idle_pool: int = (1 << n_slices) - 1 if n_slices else 0
        self._n_idle = n_slices
        self._held_by_kid: dict[int, list[int]] = {}
        self.retired: set[int] = set()
        self._pending_retire: set[int] = set()
        self._open_lends: dict[tuple[int, int], tuple[int, int, float]] = {}
        # (kid, sid) -> (owner, borrower, t_start)
        self.lent_slice_seconds = 0.0
        self.stolen_slice_seconds = 0.0
        self.n_lends = 0                             # lends ever opened
        self._owners_sorted: Optional[list[int]] = None

    # -- construction (same layout rules as SliceMap) ------------------------

    @classmethod
    def from_quotas(cls, n_slices: int,
                    quotas: dict[int, "Quota"]) -> "VecSliceMap":
        sm = cls(n_slices)
        nxt = 0
        for cid, q in sorted(quotas.items()):
            for _ in range(q.slices):
                if nxt < n_slices:
                    sm.assign_owner(nxt, cid)
                    nxt += 1
        return sm

    @classmethod
    def from_partitions(cls, n_slices: int,
                        partitions: dict[int, int]) -> "VecSliceMap":
        sm = cls(n_slices)
        nxt = 0
        for cid, n in sorted(partitions.items()):
            for _ in range(n):
                if nxt < n_slices:
                    sm.assign_owner(nxt, cid)
                    nxt += 1
        return sm

    def assign_owner(self, sid: int, cid: int):
        assert self.holder[sid] is None, "cannot re-own a held slice"
        bit = 1 << sid
        old = self.owner[sid]
        if old is None:
            self._idle_pool &= ~bit
        else:
            self._idle_own[old] &= ~bit
            self._own_mask[old] &= ~bit
        self.owner[sid] = cid
        self._idle_own[cid] = self._idle_own.get(cid, 0) | bit
        self._own_mask[cid] = self._own_mask.get(cid, 0) | bit
        self._idle_owned_union |= bit
        self._owners_sorted = None

    def disown(self, sid: int):
        """See :meth:`SliceMap.disown` — same elastic-release semantics on
        the bitmask free-lists."""
        assert self.holder[sid] is None, "cannot disown a held slice"
        old = self.owner[sid]
        if old is None:
            return
        bit = 1 << sid
        self.owner[sid] = None
        self._idle_own[old] &= ~bit
        self._own_mask[old] &= ~bit
        if not self._own_mask[old]:
            del self._idle_own[old]
            del self._own_mask[old]
        self._idle_owned_union &= ~bit
        self._idle_pool |= bit
        self._owners_sorted = None

    def retire(self, sid: int) -> bool:
        """See :meth:`SliceMap.retire` — same lazy-on-held semantics on the
        bitmask free-lists."""
        if sid in self.retired:
            return True
        if self.holder[sid] is not None:
            self._pending_retire.add(sid)
            return False
        self._do_retire(sid)
        return True

    def _do_retire(self, sid: int):
        bit = 1 << sid
        o = self.owner[sid]
        if o is None:
            self._idle_pool &= ~bit
        else:
            self._idle_own[o] &= ~bit
            self._own_mask[o] &= ~bit
            if not self._own_mask[o]:
                del self._idle_own[o]
                del self._own_mask[o]
            self._idle_owned_union &= ~bit
            self._owners_sorted = None
        self.owner[sid] = None
        self._n_idle -= 1
        self.retired.add(sid)

    # -- queries -------------------------------------------------------------

    def owners(self) -> list[int]:
        if self._owners_sorted is None:
            self._owners_sorted = sorted(self._idle_own.keys())
        return self._owners_sorted

    def owned_by(self, cid: int) -> int:
        return self._own_mask.get(cid, 0).bit_count()

    def idle_owned(self, cid: int) -> list[int]:
        return _mask_bits(self._idle_own.get(cid, 0))

    def n_own_idle(self, cid: int) -> int:
        return self._idle_own.get(cid, 0).bit_count()

    def idle_pool(self) -> list[int]:
        return _mask_bits(self._idle_pool)

    def total_idle(self) -> int:
        return self._n_idle

    def n_owned_idle_total(self) -> int:
        return self._n_idle - self._idle_pool.bit_count()

    def idle_owners(self) -> list[int]:
        return [o for o in self.owners() if self._idle_own[o]]

    def idle_stealable(self, borrower: int,
                       lenders: Iterable[int]) -> list[int]:
        m = 0
        for o in lenders:
            if o == borrower:
                continue
            m |= self._idle_own.get(o, 0)
        return _mask_bits(m)

    def free_for(self, borrower: int, *, lenders: Iterable[int] = (),
                 include_pool: bool = True) -> list[int]:
        free = self.idle_owned(borrower)
        if include_pool:
            free += self.idle_pool()
        free += self.idle_stealable(borrower, lenders)
        return free

    # -- mask fast path (vectorized dispatch) --------------------------------

    def idle_own_mask(self, cid: int) -> int:
        return self._idle_own.get(cid, 0)

    def own_mask(self, cid: int) -> int:
        return self._own_mask.get(cid, 0)

    def idle_owned_union(self) -> int:
        """Union of every owner's idle mask (excludes the unowned pool)."""
        return self._idle_owned_union

    def take_free(self, borrower: int, want: int, steal_mask: int,
                  include_pool: bool = True) -> tuple[list[int], int]:
        """First-``want`` free slice ids in the reference ``free_for``
        order — own idle ascending, then pool ascending, then the
        stealable union ascending — plus the total free count.  The
        mask-only equivalent of ``free_for(...)[:want]`` without
        materializing the full id list."""
        own = self._idle_own.get(borrower, 0)
        pool = self._idle_pool if include_pool else 0
        n = own.bit_count() + pool.bit_count() + steal_mask.bit_count()
        if want > n:
            want = n
        out: list[int] = []
        for m in (own, pool, steal_mask):
            while m and len(out) < want:
                b = m & -m
                out.append(b.bit_length() - 1)
                m ^= b
            if len(out) >= want:
                break
        return out, n

    def held_by(self, kid: int) -> tuple[int, ...]:
        return tuple(self._held_by_kid.get(kid, ()))

    # -- transitions ---------------------------------------------------------

    def acquire(self, slice_ids: Sequence[int], kid: int, borrower: int,
                now: float, eta: Optional[float] = None) -> bool:
        stolen = False
        held = self._held_by_kid.get(kid)
        if held is None:
            held = self._held_by_kid[kid] = []
        holder, busy, owner = self.holder, self.busy_until, self.owner
        idle_own = self._idle_own
        pool = self._idle_pool
        union = self._idle_owned_union
        idle_before = pool | union
        bu = now + eta if eta is not None else None
        m = 0
        for sid in slice_ids:
            bit = 1 << sid
            m |= bit
            o = owner[sid]
            holder[sid] = kid
            busy[sid] = bu if bu is not None else max(busy[sid], now)
            if o is None:
                pool &= ~bit
            else:
                idle_own[o] &= ~bit
                union &= ~bit
                if o != borrower:
                    stolen = True
                    self._open_lends[(kid, sid)] = (o, borrower, now)
                    self.n_lends += 1
            held.append(sid)
        # every acquired slice must have been idle (the per-sid holder
        # check of the reference map, done as one mask comparison)
        assert m & idle_before == m, (kid, slice_ids)
        self._idle_pool = pool
        self._idle_owned_union = union
        self._n_idle -= len(slice_ids)
        return stolen

    def release(self, kid: int, now: float) -> tuple[int, ...]:
        freed = self._held_by_kid.pop(kid, [])
        holder, busy, owner = self.holder, self.busy_until, self.owner
        idle_own = self._idle_own
        pool = self._idle_pool
        union = self._idle_owned_union
        lends = self._open_lends
        lent = self.lent_slice_seconds
        for sid in freed:
            bit = 1 << sid
            holder[sid] = None
            busy[sid] = now
            o = owner[sid]
            if o is None:
                pool |= bit
            else:
                idle_own[o] |= bit
                union |= bit
                if lends:
                    lend = lends.pop((kid, sid), None)
                    if lend is not None:
                        lent += now - lend[2]
        self._idle_pool = pool
        self._idle_owned_union = union
        self.lent_slice_seconds = lent
        self._n_idle += len(freed)
        if self._pending_retire:
            for sid in freed:
                if sid in self._pending_retire:
                    self._pending_retire.discard(sid)
                    self._do_retire(sid)
        return tuple(freed)

    def note_stolen_completion(self, latency: float, slices: int):
        self.stolen_slice_seconds += latency * slices

    # -- invariants ----------------------------------------------------------

    def counts(self) -> dict[str, int]:
        owned_idle = sum(m.bit_count() for m in self._idle_own.values())
        pool_idle = self._idle_pool.bit_count()
        return {"owned_idle": owned_idle, "pool_idle": pool_idle,
                "held": (self.n_slices - owned_idle - pool_idle
                         - len(self.retired)),
                "lent": len(self._open_lends),
                "retired": len(self.retired)}

    def check(self):
        held: set[int] = set()
        for kid, ids in self._held_by_kid.items():
            for sid in ids:
                assert sid not in held, f"slice {sid} held twice"
                assert self.holder[sid] == kid, (sid, kid, self.holder[sid])
                held.add(sid)
        idle: set[int] = set()
        for cid, m in self._idle_own.items():
            for sid in _mask_bits(m):
                assert self.owner[sid] == cid
                assert sid not in idle
                idle.add(sid)
        for sid in _mask_bits(self._idle_pool):
            assert self.owner[sid] is None
            assert sid not in idle
            idle.add(sid)
        assert not (held & idle), held & idle
        for sid in self.retired:
            assert self.holder[sid] is None and self.owner[sid] is None, sid
            assert sid not in held and sid not in idle, sid
        assert self._pending_retire <= held, (self._pending_retire, held)
        assert len(held) + len(idle) + len(self.retired) == self.n_slices, (
            len(held), len(idle), len(self.retired), self.n_slices)
        assert len(idle) == self._n_idle, (len(idle), self._n_idle)
        for sid in idle:
            assert self.holder[sid] is None, sid
        for kid, sid in self._open_lends:
            assert self.holder[sid] == kid
            assert self.owner[sid] is not None
        return True


# ---------------------------------------------------------------------------
# Member-level lending ledger (cross-member stealing, any hierarchy tier)
# ---------------------------------------------------------------------------

@dataclass
class MemberLendRecord:
    """One client queue hosted away from its home member.

    The hierarchy-scale mirror of :class:`LendRecord`: instead of one slice
    lent across an ownership boundary for one kernel, this is one *member's
    worth of stealable capacity* lent across a member boundary for one
    migration interval — a device boundary for the node tier, a node
    boundary for the cluster tier.  ``home`` is the member the router placed
    the client on (the saturated borrower of help); ``host`` is the idle
    member donating its capacity by hosting the queue."""

    cid: int
    home: int
    host: int
    t_start: float
    t_end: Optional[float] = None   # None while the client is away

    @property
    def open(self) -> bool:
        return self.t_end is None

    @property
    def duration(self) -> float:
        return 0.0 if self.t_end is None else self.t_end - self.t_start


class MemberLedger:
    """Cross-member donation bookkeeping for one hierarchy tier's
    coordinator (devices of a node; nodes of a cluster).

    Tracks each client's home (router placement) and current member, records
    a :class:`MemberLendRecord` per away interval, and extends the SliceMap
    conservation story across the tier: at any instant every client is
    hosted by exactly one member, and the open records are exactly the
    clients hosted off their home member.

    ``placement`` maps cid -> member: either a dict, or a sequence indexed
    by cid (the node tier's app-ordered placement list)."""

    def __init__(self, n_members: int, placement):
        self.n_members = n_members
        base = (dict(placement) if isinstance(placement, dict)
                else dict(enumerate(placement)))
        self.home: dict[int, int] = dict(base)
        self.current: dict[int, int] = dict(base)
        self.ledger: list[MemberLendRecord] = []
        self._open: dict[int, MemberLendRecord] = {}
        self.lent_client_seconds = 0.0  # closed away-intervals, from ledger
        self.n_migrations = 0

    @property
    def n_devices(self) -> int:     # node-tier alias
        return self.n_members

    def migrate(self, cid: int, dst: int, now: float):
        """Record that ``cid``'s launch queue moved to member ``dst``."""
        assert 0 <= dst < self.n_members
        src = self.current[cid]
        assert dst != src, (cid, dst)
        rec = self._open.pop(cid, None)
        if rec is not None:             # returning home or re-lending
            assert now >= rec.t_start, (cid, now, rec.t_start)
            rec.t_end = now
            self.lent_client_seconds += rec.duration
        if dst != self.home[cid]:
            nr = MemberLendRecord(cid, self.home[cid], dst, now)
            self.ledger.append(nr)
            self._open[cid] = nr
        self.current[cid] = dst
        self.n_migrations += 1

    def drop(self, cid: int, now: float):
        """Forget ``cid`` — a higher tier migrated it out of this tier's
        scope.  Any open away-interval is closed (the donation ended when
        the client left the tier)."""
        rec = self._open.pop(cid, None)
        if rec is not None:
            assert now >= rec.t_start, (cid, now, rec.t_start)
            rec.t_end = now
            self.lent_client_seconds += rec.duration
        del self.current[cid]
        del self.home[cid]

    def adopt(self, cid: int, member: int):
        """Register ``cid`` as newly hosted in this tier's scope — a higher
        tier migrated it in.  The landing member becomes its home (the
        client was freshly placed there, not lent within this tier)."""
        assert cid not in self.current, cid
        assert 0 <= member < self.n_members
        self.home[cid] = member
        self.current[cid] = member

    def donated_seconds(self, now: float) -> float:
        """Total away time including still-open intervals."""
        return self.lent_client_seconds + sum(
            now - r.t_start for r in self._open.values())

    def check(self, hosted: Optional[dict[int, int]] = None):
        """Conservation across members: the hosted map (cid -> member, from
        the live simulators) matches ``current``; open records are exactly
        the off-home clients; closed durations sum to the counter."""
        if hosted is not None:
            assert hosted == self.current, (hosted, self.current)
        off_home = {cid for cid, d in self.current.items()
                    if d != self.home[cid]}
        assert set(self._open) == off_home, (set(self._open), off_home)
        for cid, rec in self._open.items():
            assert rec.open and rec.host == self.current[cid]
            assert rec.home == self.home[cid]
        closed = sum(r.duration for r in self.ledger if not r.open)
        assert abs(closed - self.lent_client_seconds) < 1e-9
        return True


# Node-tier names, kept for callers that predate the hierarchy refactor.
NodeLendRecord = MemberLendRecord
NodeLedger = MemberLedger
