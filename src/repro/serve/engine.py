"""Slot-based continuous-batching serving engine.

A fixed pool of ``max_slots`` decode slots shares one KV-cache allocation
(static shapes — pjit-able).  Requests prefill at batch 1 and their caches
are scattered into a free slot; every engine iteration decodes *all* active
slots in one batched ``serve_decode`` call; finished slots (EOS or
max-tokens) free immediately and admit queued requests — the standard
continuous-batching discipline (Orca/vLLM style) expressed in pure JAX.

SLO accounting mirrors the paper's measurement: per-request end-to-end
latency (arrival -> last token) and time-to-first-token.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.registry import init_model

PyTree = Any


@dataclass
class ServeConfig:
    max_slots: int = 4
    max_len: int = 256
    max_new_tokens: int = 32
    eos_id: int = 1
    greedy: bool = True


@dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # [S] prompt
    arrival: float = 0.0
    max_new_tokens: Optional[int] = None
    # filled by the engine
    output: list[int] = field(default_factory=list)
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None


class SlotServer:
    """Continuous-batching server for decoder-only configs."""

    def __init__(self, cfg: ArchConfig, params: Optional[PyTree] = None, *,
                 serve_cfg: Optional[ServeConfig] = None, seed: int = 0,
                 clock: Optional[Callable[[], float]] = None):
        assert not cfg.is_encoder_decoder, "SlotServer serves decoder LMs"
        self.cfg = cfg
        # a ServeConfig() default argument would be evaluated once and
        # shared by every server — mutating one server's sc (e.g. tuning
        # max_new_tokens) would silently retune all of them
        self.sc = serve_cfg if serve_cfg is not None else ServeConfig()
        self.params = (params if params is not None
                       else init_model(cfg, jax.random.PRNGKey(seed)))
        self.clock = clock or (lambda: 0.0)
        B, L = self.sc.max_slots, self.sc.max_len
        self.caches = transformer.init_caches(cfg, B, L)
        self.pos = np.zeros(B, np.int64)            # next position per slot
        self.budget = np.zeros(B, np.int64)         # tokens left per slot
        self.active = np.zeros(B, bool)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self._rid = itertools.count()
        self._last = jnp.zeros(B, jnp.int32)        # last sampled token

        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # -- jitted compute ----------------------------------------------------------

    def _prefill_impl(self, params, tokens, caches, slot):
        """Batch-1 prefill; scatter the new caches into ``slot``."""
        logits, new1 = transformer.prefill(params, self.cfg, tokens,
                                           max_len=self.sc.max_len)

        def scatter(full, one):
            # full: [B, ...] or [G, B, ...] (scanned layers); one: B=1.
            # The slot axis is the first axis where shapes differ.
            axis = next(i for i in range(one.ndim)
                        if one.shape[i] != full.shape[i])
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=axis)

        merged = jax.tree.map(scatter, caches, new1)
        return logits[0], merged

    def _decode_impl(self, params, tokens, pos, caches, active):
        """One decode step over all slots (per-slot positions); inactive
        slots still compute (static shapes) but their outputs are ignored."""
        logits, new_caches = transformer.decode_step(
            params, self.cfg, tokens, pos, caches)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, new_caches

    # -- public API -----------------------------------------------------------------

    def submit(self, tokens: np.ndarray,
               max_new_tokens: Optional[int] = None) -> Request:
        req = Request(next(self._rid), np.asarray(tokens, np.int32),
                      arrival=self.clock(),
                      max_new_tokens=max_new_tokens)
        self.queue.append(req)
        return req

    def _admit(self):
        for slot in range(self.sc.max_slots):
            if self.active[slot] or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = req.tokens[-(self.sc.max_len - 1):][None, :]
            logits, self.caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches, slot)
            first = int(jnp.argmax(logits, -1))
            req.output.append(first)
            req.t_first_token = self.clock()
            self.slot_req[slot] = req
            self.pos[slot] = toks.shape[1]
            self.budget[slot] = (req.max_new_tokens or
                                 self.sc.max_new_tokens) - 1
            self.active[slot] = True
            self._last = self._last.at[slot].set(first)
            if first == self.sc.eos_id or self.budget[slot] <= 0:
                self._finish(slot)

    def _finish(self, slot: int):
        req = self.slot_req[slot]
        req.t_finish = self.clock()
        self.done.append(req)
        self.slot_req[slot] = None
        self.active[slot] = False

    def step(self) -> int:
        """One engine iteration: admit then decode all active slots.
        Returns number of active slots decoded."""
        self._admit()
        if not self.active.any():
            return 0
        nxt, self.caches = self._decode(
            self.params, self._last, jnp.asarray(self.pos),
            self.caches, jnp.asarray(self.active))
        nxt_np = np.asarray(nxt)
        n = 0
        for slot in range(self.sc.max_slots):
            if not self.active[slot]:
                continue
            n += 1
            tok = int(nxt_np[slot])
            req = self.slot_req[slot]
            req.output.append(tok)
            self.pos[slot] += 1
            self.budget[slot] -= 1
            if (tok == self.sc.eos_id or self.budget[slot] <= 0
                    or self.pos[slot] >= self.sc.max_len - 1):
                self._finish(slot)
        self._last = nxt
        return n

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        for _ in range(max_iters):
            if not self.queue and not self.active.any():
                break
            self.step()
        return self.done

    # -- metrics ------------------------------------------------------------------------

    def latencies(self) -> list[float]:
        return [r.t_finish - r.arrival for r in self.done
                if r.t_finish is not None]
