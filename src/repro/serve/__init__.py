from repro.serve.engine import Request, ServeConfig, SlotServer
