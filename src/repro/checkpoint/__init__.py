from repro.checkpoint.sharded import (CheckpointManager, restore_checkpoint,
                                      save_checkpoint)
