"""Sharded, asynchronous checkpointing with elastic restore.

Layout (mesh-independent, so restore works onto any mesh):

    <dir>/step_<N>/
        manifest.json        # leaf path -> {shape, dtype, shard_file, kind}
        shard_<k>.npz        # leaves bin-packed by bytes into n_shards files

* **Async save**: leaves are fetched to host (blocking, cheap) and the file
  writes happen on a background thread; ``wait()`` joins.  A ``COMMIT``
  marker is written last, so partially written checkpoints are never
  restored (crash-consistent).
* **Elastic restore**: the manifest stores logical arrays only.  Restore
  reads host arrays and ``jax.device_put``s them with shardings resolved
  against the *current* mesh — loading a 512-chip checkpoint onto 256 chips
  (or onto 1 CPU device in tests) is the same code path.
* QTensor optimizer moments round-trip via their pytree (q, scale) leaves.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "##"

# dtypes npz cannot store natively: persisted as raw bits + manifest dtype
try:
    import ml_dtypes
    _BITCAST = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
                "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
                "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2)}
except ImportError:                                      # pragma: no cover
    _BITCAST = {}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    enc = _BITCAST.get(str(arr.dtype))
    return arr.view(enc[0]) if enc else arr


def _from_storable(arr: np.ndarray, dtype: str) -> np.ndarray:
    enc = _BITCAST.get(dtype)
    return arr.view(enc[1]) if enc else arr


def _flatten(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = leaf
    return out


def save_checkpoint(tree: PyTree, directory: str, step: int, *,
                    n_shards: int = 4, async_write: bool = True
                    ) -> "SaveHandle":
    """Write ``tree`` under ``directory/step_<step>``; returns a handle
    whose ``wait()`` blocks until the COMMIT marker is on disk."""
    leaves = _flatten(tree)
    host = {k: np.asarray(v) for k, v in leaves.items()}   # fetch now
    stepdir = os.path.join(directory, f"step_{step}")
    tmpdir = stepdir + ".tmp"

    def write():
        os.makedirs(tmpdir, exist_ok=True)
        # bin-pack leaves into shards by bytes (largest first)
        order = sorted(host, key=lambda k: -host[k].nbytes)
        bins: list[tuple[int, list[str]]] = [(0, []) for _ in range(n_shards)]
        for k in order:
            i = min(range(n_shards), key=lambda j: bins[j][0])
            bins[i] = (bins[i][0] + host[k].nbytes, bins[i][1] + [k])
        manifest = {}
        for i, (_, keys) in enumerate(bins):
            if not keys:
                continue
            fname = f"shard_{i}.npz"
            np.savez(os.path.join(tmpdir, fname),
                     **{k: _to_storable(host[k]) for k in keys})
            for k in keys:
                manifest[k] = {"shape": list(host[k].shape),
                               "dtype": str(host[k].dtype),
                               "shard": fname}
        with open(os.path.join(tmpdir, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
        open(os.path.join(tmpdir, "COMMIT"), "w").close()
        if os.path.isdir(stepdir):
            shutil.rmtree(stepdir)
        os.rename(tmpdir, stepdir)
        handle.committed = True

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        handle = SaveHandle(t, stepdir)
        t.start()
        return handle
    handle = SaveHandle(None, stepdir)
    write()
    return handle


class SaveHandle:
    def __init__(self, thread: Optional[threading.Thread], path: str):
        self._thread = thread
        self.path = path
        self.committed = False

    def wait(self):
        if self._thread is not None:
            self._thread.join()
        # the committed dir may have been GC'd (keep-last-k) by a later
        # save; the flag records that the write itself succeeded
        assert self.committed, f"checkpoint {self.path} did not commit"


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMIT")):
                steps.append(int(name.split("_", 1)[1]))
    return max(steps) if steps else None


def restore_checkpoint(template: PyTree, directory: str,
                       step: Optional[int] = None, *,
                       sharding_fn: Optional[Callable[[str], Any]] = None
                       ) -> PyTree:
    """Restore into the structure of ``template``.  ``sharding_fn(key)``
    may return a Sharding per leaf (elastic re-shard onto the current
    mesh); None leaves it to JAX's default placement."""
    step = latest_step(directory) if step is None else step
    assert step is not None, f"no committed checkpoint under {directory}"
    stepdir = os.path.join(directory, f"step_{step}")
    with open(os.path.join(stepdir, "manifest.json")) as f:
        manifest = json.load(f)["leaves"]
    shard_cache: dict[str, Any] = {}

    keys_tmpl = _flatten(template)
    missing = set(keys_tmpl) - set(manifest)
    extra = set(manifest) - set(keys_tmpl)
    assert not missing, f"checkpoint missing leaves: {sorted(missing)[:5]}"
    assert not extra, f"checkpoint has extra leaves: {sorted(extra)[:5]}"

    out = {}
    for key, tmpl_leaf in keys_tmpl.items():
        meta = manifest[key]
        if meta["shard"] not in shard_cache:
            shard_cache[meta["shard"]] = np.load(
                os.path.join(stepdir, meta["shard"]))
        arr = _from_storable(shard_cache[meta["shard"]][key], meta["dtype"])
        assert tuple(arr.shape) == tuple(tmpl_leaf.shape), \
            (key, arr.shape, tmpl_leaf.shape)
        sh = sharding_fn(key) if sharding_fn is not None else None
        out[key] = (jax.device_put(arr, sh) if sh is not None
                    else jax.numpy.asarray(arr).astype(tmpl_leaf.dtype))

    # rebuild tree in template order
    flat, treedef = jax.tree_util.tree_flatten(template)
    keys_in_order = list(keys_tmpl)
    return jax.tree_util.tree_unflatten(
        treedef, [out[k] for k in keys_in_order])


class CheckpointManager:
    """keep-last-k rotation + convenience save/restore for TrainState."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 4):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        self._handles: list[SaveHandle] = []

    def save(self, tree: PyTree, step: int, async_write: bool = True):
        # one outstanding async save: a new snapshot waits for the previous
        # write to commit (bounds host-memory staging and avoids GC races)
        if self._handles:
            self._handles[-1].wait()
        h = save_checkpoint(tree, self.directory, step,
                            n_shards=self.n_shards, async_write=async_write)
        self._handles.append(h)
        self._gc()
        return h

    def wait_all(self):
        for h in self._handles:
            h.wait()
        self._handles.clear()
        self._gc()          # async commits may land after save-time GC

    def restore(self, template: PyTree, step: Optional[int] = None,
                sharding_fn=None) -> PyTree:
        return restore_checkpoint(template, self.directory, step,
                                  sharding_fn=sharding_fn)

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n.split("_", 1)[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, "COMMIT")))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
