"""grok-1-314b — MoE with 8 experts, top-2 routing [hf:xai-org/grok-1].

8 experts < 16-way ``model`` axis, so the default is TP-within-expert
(d_ff 32768 sharded 16-way per expert); EP mode would pad 8 -> 16 (2x waste).
Grok-style tanh logit soft-capping at 30.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    source="hf:xai-org/grok-1",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    logit_softcap=30.0,
    moe=MoEConfig(
        n_experts=8,
        top_k=2,
        n_shared_experts=0,
        expert_d_ff=32768,
        capacity_factor=1.25,
        parallelism="tp",
    ),
    attention_class="quadratic",
    moment_dtype="int8",
)
