"""qwen1.5-32b — dense MHA (kv == q heads) with QKV bias [hf:Qwen/Qwen1.5-0.5B
family scaling].

40 heads on a 16-way ``model`` axis do not divide evenly; GSPMD pad-shards the
head dim (40 -> ceil(40/16)*16 = 48 slots, 8 padded).  Documented in
DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B (family config, scaled per assignment)",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attention_class="quadratic",
)
