"""Architecture & shape configuration system.

Every assigned architecture gets one ``<id>.py`` module in this package exposing
``CONFIG: ArchConfig``.  The registry maps ``--arch <id>`` to that config.

Configs are *exact* per the assignment table (public-literature sources recorded
in each file).  ``ArchConfig.reduced()`` produces a same-family shrunken config
for CPU smoke tests; the full config is only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence


# ---------------------------------------------------------------------------
# Shape configs (shared by all LM-family archs per the assignment)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (name, seq_len, global_batch, kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES: tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    expert_d_ff: int = 0          # d_ff per routed expert
    shared_d_ff: int = 0          # d_ff per shared expert
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # "ep": experts sharded over the model axis; "tp": d_ff sharded per expert.
    parallelism: str = "ep"


@dataclass(frozen=True)
class HybridConfig:
    """Layer-pattern description for hybrid / mixed-block stacks.

    ``pattern`` is a tuple of block kinds applied cyclically, e.g.
    ``("rec", "rec", "attn")`` for RecurrentGemma's 1:2 local-attn ratio or
    ``("mlstm",)*7 + ("slstm",)`` for xLSTM[7:1].
    """

    pattern: tuple[str, ...]
    window: int = 0               # sliding-attention window (local attn blocks)
    lru_width: int = 0            # RG-LRU recurrence width (0 => d_model)
    conv_width: int = 4           # temporal-conv width in recurrent blocks


@dataclass(frozen=True)
class ArchConfig:
    # -- identity ------------------------------------------------------------
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    source: str                   # public-literature citation string
    # -- transformer backbone (assignment table values) -----------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    # -- family knobs ---------------------------------------------------------
    d_head: int = 0               # 0 => d_model // n_heads
    activation: str = "swiglu"    # swiglu | sq_relu | gelu
    norm: str = "rmsnorm"         # rmsnorm | layernorm | nonparam_ln
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0    # grok/gemma-style tanh soft-capping (0 = off)
    moe: Optional[MoEConfig] = None
    hybrid: Optional[HybridConfig] = None
    # -- enc-dec (whisper) ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    max_source_positions: int = 1500   # whisper: 30 s of audio frames
    # -- modality frontend stubs ----------------------------------------------
    # "none": token ids.  "patch_stub"/"frame_stub": input_specs() provides
    # precomputed patch/frame embeddings of width ``d_model`` (per assignment).
    frontend: str = "none"
    # -- attention complexity class (drives long_500k applicability) ----------
    #   "quadratic": full attention  -> long_500k skipped
    #   "subquadratic": SSM / recurrent / windowed -> long_500k runs
    attention_class: str = "quadratic"
    # -- numerics --------------------------------------------------------------
    dtype: str = "bfloat16"
    # optimizer-moment dtype: "float32" | "bfloat16" | "int8" (block-quantized)
    moment_dtype: str = "float32"

    # -- derived ---------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    def param_count(self) -> int:
        """Analytical parameter count (used for 6ND model-FLOPs and memory math)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * hd
        if self.moe is not None:
            m = self.moe
            routed = m.n_experts * 3 * d * m.expert_d_ff
            shared = m.n_shared_experts * 3 * d * m.shared_d_ff
            router = d * m.n_experts
            ffn = routed + shared + router
        elif self.activation in ("swiglu", "geglu"):
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        block = attn + ffn
        if self.hybrid is not None:
            block = self._hybrid_block_params()
        emb = V * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.is_encoder_decoder:
            # encoder self-attn (MHA, kv == q heads) + ffn + decoder cross-attn
            enc_attn = 4 * d * d
            enc_ffn = 2 * d * self.d_ff
            enc = self.n_encoder_layers * (enc_attn + enc_ffn)
            block += 4 * d * d  # decoder cross-attention
        return L * block + emb + enc

    def _hybrid_block_params(self) -> int:
        """Average per-layer params for pattern-mixed stacks."""
        assert self.hybrid is not None
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        h = self.hybrid
        per_kind = {}
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.activation in ("swiglu", "geglu"):
            ffn = 3 * d * self.d_ff
        else:
            ffn = 2 * d * self.d_ff
        per_kind["attn"] = attn + ffn
        w = h.lru_width or d
        # RG-LRU block: in/out proj + gates + conv
        per_kind["rec"] = 2 * d * w + 2 * w * w // 8 + h.conv_width * w + ffn
        # mLSTM: qkv + out + gates; sLSTM: recurrent gates (4 gates, block-diag)
        per_kind["mlstm"] = 4 * d * d + 2 * d
        per_kind["slstm"] = 8 * d * d // max(1, self.n_heads) * self.n_heads // 4 + 4 * d * d
        total = sum(per_kind.get(k, attn + ffn) for k in h.pattern)
        return total // len(h.pattern)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        m = self.moe
        full = self.param_count()
        routed_all = L * m.n_experts * 3 * d * m.expert_d_ff
        routed_active = L * m.top_k * 3 * d * m.expert_d_ff
        return full - routed_all + routed_active

    # -- smoke-test reduction --------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(1, self.n_heads))),
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            d_head=16,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2,
                n_shared_experts=min(1, self.moe.n_shared_experts),
                expert_d_ff=32, shared_d_ff=32 if self.moe.n_shared_experts else 0)
        if self.hybrid is not None:
            pat = self.hybrid.pattern
            kw["hybrid"] = dataclasses.replace(
                self.hybrid, window=32, lru_width=64 if self.hybrid.lru_width else 0)
            kw["n_layers"] = len(pat)  # one full pattern period
        if self.is_encoder_decoder:
            kw["n_encoder_layers"] = 2
            kw["max_source_positions"] = 64
        return dataclasses.replace(self, **kw)

    def shapes(self) -> tuple[ShapeConfig, ...]:
        """The shape cells assigned to this arch (incl. inapplicable ones)."""
        return ALL_SHAPES

    def shape_applicable(self, shape: ShapeConfig) -> tuple[bool, str]:
        """(runs?, reason-if-skipped) per assignment rules."""
        if self.is_encoder_decoder and shape.seq_len > 448 \
                and shape.kind != "train":
            return False, ("whisper decoder context is 448 tokens by "
                           "construction; 32k/500k decoder prompts/KV "
                           "inapplicable")
        if shape.name == "long_500k" and self.attention_class == "quadratic":
            return False, "full-attention O(S^2); long-context decode skipped per spec"
        return True, ""

    def effective_seq(self, shape: ShapeConfig) -> int:
        """Decoder sequence actually lowered for this cell.  Whisper's
        decoder is 448 tokens by construction, so train_4k clips the target
        length (documented in DESIGN.md §Arch-applicability)."""
        if self.is_encoder_decoder:
            return min(shape.seq_len, 448)
        return shape.seq_len
