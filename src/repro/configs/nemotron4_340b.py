"""nemotron-4-340b — dense GQA, squared-ReLU MLP [arXiv:2402.16819].

Largest dense config.  6.2T params of optimizer state in fp32 would not fit
v5e HBM on 256 chips; the config defaults to int8 block-quantized moments
(see DESIGN.md §6 napkin math).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    source="arXiv:2402.16819 (Nemotron-4 340B)",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="sq_relu",
    norm="layernorm",
    rope_theta=10_000.0,
    attention_class="quadratic",
    moment_dtype="int8",
)
