"""qwen2-moe-a2.7b — MoE with 60 routed experts (top-4) + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].

Expert parallelism over the ``model`` axis (60 experts -> 64 slots, GSPMD
pad-shards; 4 idle slots documented in DESIGN.md).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        n_shared_experts=4,
        expert_d_ff=1408,
        shared_d_ff=1408,
        capacity_factor=1.25,
        parallelism="ep",
    ),
    attention_class="quadratic",
)
