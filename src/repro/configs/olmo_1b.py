"""olmo-1b — dense MHA with non-parametric LayerNorm, tied embeddings
[arXiv:2402.00838]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    source="arXiv:2402.00838 (OLMo)",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    activation="swiglu",
    norm="nonparam_ln",
    tie_embeddings=True,
    rope_theta=10_000.0,
    attention_class="quadratic",
)
