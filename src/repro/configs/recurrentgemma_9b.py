"""recurrentgemma-9b — Griffin-style hybrid: RG-LRU + local attention, 1:2
attention:recurrence ratio [arXiv:2402.19427].

Pattern (rec, rec, attn) cyclic; 38 layers = 12 periods + 2 remainder rec
blocks.  Local attention window 2048, MQA (1 KV head).  Sub-quadratic
(recurrent state + bounded window) so the ``long_500k`` cell runs.
"""
from repro.configs.base import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin) / RecurrentGemma-9B",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    tie_embeddings=True,
    hybrid=HybridConfig(
        pattern=("rec", "rec", "attn"),
        window=2048,
        lru_width=4096,
        conv_width=4,
    ),
    attention_class="subquadratic",
)
