"""``--arch <id>`` registry over the assigned architecture configs."""
from __future__ import annotations

import importlib

from repro.configs.base import (ALL_SHAPES, SHAPES_BY_NAME, ArchConfig,
                                ShapeConfig)

_MODULES = {
    "llama3-8b": "repro.configs.llama3_8b",
    "nemotron-4-340b": "repro.configs.nemotron4_340b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "olmo-1b": "repro.configs.olmo_1b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "whisper-small": "repro.configs.whisper_small",
}

ARCH_IDS: tuple[str, ...] = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown --arch {arch_id!r}; choose from {ARCH_IDS}")
    cfg = importlib.import_module(_MODULES[arch_id]).CONFIG
    assert cfg.name == arch_id, (cfg.name, arch_id)
    return cfg


def get_shape(shape_name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[shape_name]


def all_cells() -> list[tuple[ArchConfig, ShapeConfig]]:
    """All 40 (arch x shape) assignment cells, including documented skips."""
    return [(get_config(a), s) for a in ARCH_IDS for s in ALL_SHAPES]


def runnable_cells() -> list[tuple[ArchConfig, ShapeConfig]]:
    return [(c, s) for c, s in all_cells() if c.shape_applicable(s)[0]]
