"""llava-next-34b — VLM backbone (Yi-34B-class decoder) with anyres tiling
frontend STUB [hf:llava-hf/llava-v1.6-mistral-7b-hf family].

Per the assignment the modality frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings [B, S, d_model]; the vision tower/anyres tiler is
out of scope.  ``vlm_proj`` (the multimodal projector) is real.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-34b (Yi-34B backbone)",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=5_000_000.0,
    frontend="patch_stub",
    attention_class="quadratic",
)
