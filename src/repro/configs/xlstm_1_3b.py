"""xlstm-1.3b — sLSTM + mLSTM block stack [arXiv:2405.04517].

xLSTM[7:1] ratio: 7 mLSTM blocks per sLSTM block, cyclic; 48 layers = 6 full
periods.  Attention-free (recurrent state decode, O(1) per token) so the
``long_500k`` cell runs.
"""
from repro.configs.base import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517 (xLSTM)",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                       # mLSTM/sLSTM blocks carry their own FFN paths
    vocab_size=50304,
    norm="rmsnorm",
    hybrid=HybridConfig(pattern=("mlstm",) * 7 + ("slstm",), conv_width=4),
    attention_class="subquadratic",
)
