"""whisper-small — encoder-decoder with conv frontend STUB [arXiv:2212.04356].

Per the assignment the mel/conv frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings [B, 1500, d_model] (30 s of audio after the 2x
conv downsampling).  Decoder context is 448 tokens by construction, so
decode_32k / long_500k are skipped (documented skip).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    source="arXiv:2212.04356 (Whisper)",
    n_layers=12,                  # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
    is_encoder_decoder=True,
    n_encoder_layers=12,
    max_source_positions=1500,
    frontend="frame_stub",
    attention_class="quadratic",
)
