from repro.data.pipeline import (DataConfig, SyntheticLM, make_batch_specs,
                                 sharded_batches)
