"""Deterministic synthetic data pipeline with sequence packing.

Generates a reproducible token stream (per-shard seeded Markov-ish mixture —
enough structure that the LM loss decreases), packs variable-length
documents into fixed-length training rows with an EOS-delimited mask, and
shards the global batch over the mesh's data axes.

Every host generates only its shard (global_batch // data_shards rows), so
the pipeline scales to any mesh without a central reader.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 1
    pad_id: int = 0


class SyntheticLM:
    """Deterministic, shardable synthetic LM corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        c = self.cfg
        n = int(rng.integers(c.mean_doc_len // 4, c.mean_doc_len * 2))
        # structured stream: random walk over token ids => learnable bigrams
        base = rng.integers(2, c.vocab_size, dtype=np.int64)
        steps = rng.integers(-64, 65, size=n)
        toks = (base + np.cumsum(steps)) % (c.vocab_size - 2) + 2
        return toks.astype(np.int32)

    def packed_rows(self, shard: int, n_shards: int,
                    start_step: int = 0) -> Iterator[np.ndarray]:
        """Yields [rows_per_shard, seq_len+1] packed token rows forever."""
        c = self.cfg
        rows = max(1, c.global_batch // n_shards)
        rng = np.random.default_rng((c.seed, shard))
        # fast-forward determinism: fold the step into the seed per batch
        step = start_step
        buf = np.empty(0, np.int32)
        while True:
            out = np.empty((rows, c.seq_len + 1), np.int32)
            for r in range(rows):
                while buf.size < c.seq_len + 1:
                    doc = self._doc(rng)
                    buf = np.concatenate([buf, doc, [c.eos_id]])
                out[r] = buf[:c.seq_len + 1]
                buf = buf[c.seq_len + 1:]
            step += 1
            yield out

    def batches(self, shard: int = 0, n_shards: int = 1
                ) -> Iterator[dict[str, np.ndarray]]:
        for rows in self.packed_rows(shard, n_shards):
            tokens = rows[:, :-1]
            labels = rows[:, 1:].copy()
            labels[tokens == self.cfg.pad_id] = -1
            yield {"tokens": tokens, "labels": labels}


def make_batch_specs(cfg: ArchConfig, shape: ShapeConfig,
                     dtype=jnp.int32) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one global batch (dry-run inputs)."""
    B, S = shape.global_batch, cfg.effective_seq(shape)
    if cfg.frontend == "patch_stub":
        return {"input_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                     jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), dtype)}
    if cfg.frontend == "frame_stub":
        return {"frames": jax.ShapeDtypeStruct(
                    (B, cfg.max_source_positions, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S), dtype),
                "labels": jax.ShapeDtypeStruct((B, S), dtype)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), dtype),
            "labels": jax.ShapeDtypeStruct((B, S), dtype)}


def sharded_batches(cfg: ArchConfig, shape: ShapeConfig, mesh,
                    seed: int = 0, frontend_rng: Optional[int] = None
                    ) -> Iterator[dict[str, jax.Array]]:
    """Host-side batches matching ``make_batch_specs`` shapes; tokens come
    from the synthetic corpus, stub-frontend embeddings from a seeded rng."""
    B, S = shape.global_batch, shape.seq_len
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                    seed=seed)
    src = SyntheticLM(dc).batches()
    rng = np.random.default_rng(frontend_rng if frontend_rng is not None
                                else seed + 1)
    while True:
        b = next(src)
        out: dict[str, np.ndarray] = {}
        if cfg.frontend == "patch_stub":
            out["input_embeds"] = rng.standard_normal(
                (B, S, cfg.d_model)).astype(np.float32) * 0.02
            out["labels"] = b["labels"]
        elif cfg.frontend == "frame_stub":
            out["frames"] = rng.standard_normal(
                (B, cfg.max_source_positions, cfg.d_model)
            ).astype(np.float32) * 0.02
            out["tokens"] = b["tokens"]
            out["labels"] = b["labels"]
        else:
            out = b
        yield {k: jnp.asarray(v) if v.dtype != np.float32
               else jnp.asarray(v, jnp.bfloat16) for k, v in out.items()}
