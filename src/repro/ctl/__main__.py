import sys

from repro.ctl.cli import main

sys.exit(main())
