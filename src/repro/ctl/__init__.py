"""ctl — the online serving control plane (daemon + CLI + crash recovery).

Everything before this subsystem was a *batch* world: ``evaluate()`` builds
every tenant up front, runs the clock to a horizon, and returns.  ``ctl``
puts an always-on scheduler daemon in front of the same simulator/cluster
stack so jobs arrive, run, migrate, and finish while the clock advances:

* :mod:`repro.ctl.state`  — the job state machine
  (``queued -> admitted -> running -> migrating -> done|preempted|failed``)
  with explicit, unit-testable transitions;
* :mod:`repro.ctl.store`  — the append-only JSONL journal plus the
  file-spool IPC (submissions / cancels / drain) under a ``--state-dir``,
  so every transition is durable and the daemon recovers after ``kill -9``;
* :mod:`repro.ctl.daemon` — the admission/progress loop draining the queue
  into a :class:`~repro.core.node.NodeCoordinator` via the stepping API;
* :mod:`repro.ctl.cli`    — ``submit / status / cancel / drain / daemon``
  verbs (``python -m repro.ctl ...``).
"""
from repro.ctl.state import (InvalidTransition, Job, JobEvent, JobState,
                             TERMINAL, TRANSITIONS, transition)
from repro.ctl.store import (Journal, read_heartbeat, replay, request_cancel,
                             request_drain, request_submit)
from repro.ctl.daemon import ControlPlane, DaemonConfig

__all__ = [
    "InvalidTransition", "Job", "JobEvent", "JobState", "TERMINAL",
    "TRANSITIONS", "transition", "Journal", "replay", "request_submit",
    "request_cancel", "request_drain", "read_heartbeat", "ControlPlane",
    "DaemonConfig",
]
