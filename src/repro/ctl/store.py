"""Durable store + file-spool IPC for the control plane.

Everything lives under one ``--state-dir``::

    state-dir/
      journal.jsonl     append-only transition journal (the truth)
      heartbeat.json    daemon liveness (pid, sim clock, counts)
      inbox/            CLI -> daemon spool (submit / cancel / drain files)

**Journal.**  Every state-machine transition is one JSON line, appended,
flushed and fsynced before the daemon acts on it — write-ahead logging, so
a ``kill -9`` at any instant loses at most work the control plane had not
yet acknowledged.  :func:`replay` folds the journal back through
:func:`repro.ctl.state.transition` to rebuild the job table; a torn final
line (crash mid-write) is detected and ignored.

**Spool.**  CLI verbs never talk to the daemon directly: ``submit`` writes
``<t_ns>-<job>.submit.json`` into ``inbox/`` via the atomic
write-to-temp-then-rename idiom, ``cancel`` writes a ``.cancel.json``
marker, ``drain`` a flag file.  The daemon ingests inbox files in filename
order (the nanosecond prefix makes that arrival order), journals the
resulting transition, then unlinks the file — so a crash between journal
and unlink re-ingests an already-known job id, which ingestion detects and
drops (no duplication).  ``status`` needs no IPC at all: it replays the
journal read-only.
"""
from __future__ import annotations

import json
import os
import time
import uuid
from typing import Optional

from repro.ctl.state import (TERMINAL, InvalidTransition, Job, JobEvent,
                             JobState)

JOURNAL = "journal.jsonl"
HEARTBEAT = "heartbeat.json"
INBOX = "inbox"
REJECTED = "rejected"           # inbox/rejected/ — quarantined spool files
DRAIN_FLAG = "drain.flag"

#: journal record kind for job creation (not a state-machine event: it
#: creates the QUEUED job the machine then evolves)
SUBMIT = "submit"


def _ensure_dirs(state_dir: str) -> str:
    os.makedirs(os.path.join(state_dir, INBOX), exist_ok=True)
    return state_dir


def _atomic_write(path: str, payload: dict):
    tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:6]}"
    with open(tmp, "w") as f:
        json.dump(payload, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


# ---------------------------------------------------------------------------
# Journal
# ---------------------------------------------------------------------------

class Journal:
    """Append-only JSONL transition journal (the daemon's write side)."""

    def __init__(self, state_dir: str):
        _ensure_dirs(state_dir)
        self.path = os.path.join(state_dir, JOURNAL)
        self._f = open(self.path, "a")
        self.seq = _last_seq(self.path) + 1

    def append(self, job_id: str, kind: str, **extra) -> dict:
        """Durably append one record; returns it.  ``kind`` is either
        :data:`SUBMIT` or a :class:`JobEvent` value."""
        rec = {"seq": self.seq, "wall": time.time(), "job": job_id,
               "event": kind, **extra}
        self.seq += 1
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        return rec

    def close(self):
        self._f.close()


def _read_records(path: str) -> list[dict]:
    """All intact journal records; a torn trailing line is dropped."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                break                   # torn tail from a crash mid-write
    return out


def _last_seq(path: str) -> int:
    recs = _read_records(path)
    return recs[-1]["seq"] if recs else -1


def replay(state_dir: str) -> dict[str, Job]:
    """Rebuild the job table by folding the journal through the state
    machine.  Pure read — ``status`` uses this with no daemon running."""
    jobs: dict[str, Job] = {}
    for rec in _read_records(os.path.join(state_dir, JOURNAL)):
        jid = rec["job"]
        if rec["event"] == SUBMIT:
            if jid in jobs:             # crash between journal and unlink
                continue
            jobs[jid] = Job(job_id=jid, spec=rec.get("spec", {}),
                            submitted_wall=rec["wall"])
            jobs[jid].updated_wall = rec["wall"]
            if "state" in rec:          # compacted snapshot record
                job = jobs[jid]
                try:
                    job.state = JobState(rec["state"])
                except ValueError:
                    pass                # defensive: never brick recovery
                job.recoveries = int(rec.get("recoveries", 0))
                job.migrations = int(rec.get("migrations", 0))
                job.updated_wall = rec.get("updated", rec["wall"])
                for k in ("cid", "device", "granted",
                          "admitted_sim", "ends_sim"):
                    if k in rec:
                        setattr(job, {"granted": "granted_slices"}.get(k, k),
                                rec[k])
                if "error" in rec:
                    job.error = rec["error"]
                if "result" in rec:
                    job.result = rec["result"]
            continue
        job = jobs.get(jid)
        if job is None:
            continue                    # journal truncated before SUBMIT
        try:
            job.apply(JobEvent(rec["event"]), wall=rec["wall"])
        except (ValueError, InvalidTransition):
            continue                    # defensive: never brick recovery
        # fold in the transition's data-plane payload
        for k in ("cid", "device", "granted", "admitted_sim", "ends_sim"):
            if k in rec:
                setattr(job, {"granted": "granted_slices"}.get(k, k), rec[k])
        if "error" in rec:
            job.error = rec["error"]
        if "result" in rec:
            job.result = rec["result"]
    return jobs


def compact(state_dir: str) -> int:
    """Bound journal growth: collapse every *terminal* job's history to one
    snapshot record while keeping live jobs' full histories verbatim.

    The snapshot is a SUBMIT record carrying the job's final folded state
    (``state``/``recoveries``/``migrations``/payload fields, marked
    ``compacted``), placed where the job's *last* record was so relative
    ordering against live jobs and non-job records (e.g. fault records) is
    preserved.  Replaying the compacted journal yields the same job table
    as replaying the original.  The rewrite is atomic (tmp + fsync +
    rename); callers must not hold the journal open across the call.
    Returns the number of records dropped."""
    path = os.path.join(state_dir, JOURNAL)
    recs = _read_records(path)
    if not recs:
        return 0
    jobs = replay(state_dir)
    terminal = {jid for jid, j in jobs.items() if j.state in TERMINAL}
    last_idx: dict[str, int] = {}
    for i, rec in enumerate(recs):
        if rec["job"] in terminal:
            last_idx[rec["job"]] = i
    out: list[dict] = []
    for i, rec in enumerate(recs):
        jid = rec["job"]
        if jid not in terminal:
            out.append(dict(rec))
            continue
        if last_idx[jid] != i:
            continue
        job = jobs[jid]
        snap = {"seq": 0, "wall": job.submitted_wall, "job": jid,
                "event": SUBMIT, "spec": job.spec,
                "state": job.state.value, "recoveries": job.recoveries,
                "migrations": job.migrations, "updated": job.updated_wall,
                "compacted": True}
        for key, attr in (("cid", "cid"), ("device", "device"),
                          ("granted", "granted_slices"),
                          ("admitted_sim", "admitted_sim"),
                          ("ends_sim", "ends_sim"), ("error", "error"),
                          ("result", "result")):
            val = getattr(job, attr)
            if val is not None:
                snap[key] = val
        out.append(snap)
    for seq, rec in enumerate(out):
        rec["seq"] = seq
    tmp = f"{path}.tmp.{os.getpid()}.{uuid.uuid4().hex[:6]}"
    with open(tmp, "w") as f:
        for rec in out:
            f.write(json.dumps(rec) + "\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return len(recs) - len(out)


# ---------------------------------------------------------------------------
# Spool (CLI -> daemon)
# ---------------------------------------------------------------------------

def request_submit(state_dir: str, spec: dict,
                   job_id: Optional[str] = None) -> str:
    """Queue a submission; returns the job id (caller-visible immediately,
    durable once the daemon journals it)."""
    _ensure_dirs(state_dir)
    jid = job_id or f"job-{uuid.uuid4().hex[:10]}"
    path = os.path.join(state_dir, INBOX,
                        f"{time.time_ns():020d}-{jid}.submit.json")
    _atomic_write(path, {"job_id": jid, "spec": spec, "wall": time.time()})
    return jid


def request_cancel(state_dir: str, job_id: str):
    _ensure_dirs(state_dir)
    path = os.path.join(state_dir, INBOX,
                        f"{time.time_ns():020d}-{job_id}.cancel.json")
    _atomic_write(path, {"job_id": job_id, "wall": time.time()})


def request_drain(state_dir: str):
    _ensure_dirs(state_dir)
    _atomic_write(os.path.join(state_dir, INBOX, DRAIN_FLAG),
                  {"wall": time.time()})


def _jid_from_name(name: str) -> Optional[str]:
    """Best-effort job id from a spool filename ``<t_ns>-<jid>.<verb>.json``.
    Lets the daemon journal a FAIL for a corrupt-but-identifiable submit."""
    stem = name
    for suffix in (".submit.json", ".cancel.json"):
        if stem.endswith(suffix):
            stem = stem[:-len(suffix)]
            break
    else:
        return None
    if "-" not in stem:
        return None
    prefix, jid = stem.split("-", 1)
    if not prefix.isdigit() or not jid:
        return None
    return jid


def _quarantine(inbox: str, path: str, name: str, reason: str) -> dict:
    """Move a malformed spool file to ``inbox/rejected/`` so it can never
    wedge ingestion again, and report it."""
    rejdir = os.path.join(inbox, REJECTED)
    os.makedirs(rejdir, exist_ok=True)
    dst = os.path.join(rejdir, name)
    try:
        os.replace(path, dst)
    except OSError:
        dst = path                      # raced away / unwritable: report only
    return {"name": name, "path": dst, "reason": reason,
            "job_id": _jid_from_name(name),
            "kind": ("submit" if name.endswith(".submit.json")
                     else "cancel" if name.endswith(".cancel.json")
                     else "unknown")}


def _spool_schema_error(name: str, payload) -> Optional[str]:
    """Why a decoded spool payload is unusable, or None if well-formed."""
    if not isinstance(payload, dict):
        return f"payload is {type(payload).__name__}, expected object"
    if not isinstance(payload.get("job_id"), str) or not payload["job_id"]:
        return "missing or non-string job_id"
    if name.endswith(".submit.json") and not isinstance(payload.get("spec"),
                                                        dict):
        return "missing or non-object spec"
    return None


def scan_inbox(state_dir: str) -> tuple[list[dict], list[dict], bool,
                                        list[dict]]:
    """Daemon side: (submits, cancels, drain?, rejected) in arrival order.
    Each entry carries its ``_path`` for post-ingestion unlink.

    Unreadable files (OSError) are skipped and retried next scan — they may
    be mid-rename.  Files that *decode wrongly* (truncated JSON, or a wrong
    shape: non-object payload, missing job id, submit without a spec) are
    permanent poison: they are moved to ``inbox/rejected/`` and reported in
    the fourth element so the daemon can journal a FAIL for any job id it
    can still identify from the filename."""
    inbox = os.path.join(state_dir, INBOX)
    if not os.path.isdir(inbox):
        return [], [], False, []
    submits, cancels, drain, rejected = [], [], False, []
    for name in sorted(os.listdir(inbox)):
        path = os.path.join(inbox, name)
        if name == DRAIN_FLAG:
            drain = True
            continue
        if name.endswith(".tmp") or ".tmp." in name or name == REJECTED:
            continue
        try:
            with open(path) as f:
                payload = json.load(f)
        except OSError:
            continue                    # transient: retry next scan
        except ValueError as e:         # bad JSON or not even valid UTF-8
            rejected.append(_quarantine(inbox, path, name,
                                        f"invalid JSON: {e}"))
            continue
        err = _spool_schema_error(name, payload)
        if err is not None:
            rejected.append(_quarantine(inbox, path, name, err))
            continue
        payload["_path"] = path
        if name.endswith(".submit.json"):
            submits.append(payload)
        elif name.endswith(".cancel.json"):
            cancels.append(payload)
    return submits, cancels, drain, rejected


def clear_drain(state_dir: str):
    try:
        os.unlink(os.path.join(state_dir, INBOX, DRAIN_FLAG))
    except FileNotFoundError:
        pass


def consume(entry: dict):
    """Unlink an ingested inbox file (idempotent)."""
    try:
        os.unlink(entry["_path"])
    except FileNotFoundError:
        pass


# ---------------------------------------------------------------------------
# Heartbeat
# ---------------------------------------------------------------------------

def write_heartbeat(state_dir: str, payload: dict):
    payload = {"wall": time.time(), "pid": os.getpid(), **payload}
    _atomic_write(os.path.join(state_dir, HEARTBEAT), payload)


def read_heartbeat(state_dir: str) -> Optional[dict]:
    try:
        with open(os.path.join(state_dir, HEARTBEAT)) as f:
            hb = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    pid = hb.get("pid")
    alive = False
    if isinstance(pid, int):
        try:
            os.kill(pid, 0)
            alive = True
        except (OSError, ProcessLookupError):
            alive = False
    hb["alive"] = alive
    return hb
