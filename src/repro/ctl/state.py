"""Job state machine for the serving control plane.

States follow the OS-style lifecycle::

                       +-----------------------------------------+
                       v                                         | requeue
    (submit) --> QUEUED --admit--> ADMITTED --start--> RUNNING --+
       |            |                  |                |  ^  \\
       |            |cancel            |cancel          |  |   \\--finish--> DONE
       |            v                  |preempt  migrate|  |land
       +--fail--> FAILED/CANCELLED <---+                v  |
                       ^                            MIGRATING
                       |  cancel/preempt/fail           |
                       +--------------------------------+

``DONE`` / ``FAILED`` / ``CANCELLED`` are terminal (absorbing).
``PREEMPTED`` is *not* terminal: a preempted job (daemon drain, or a crash
discovered at recovery) re-enters the queue via ``requeue`` and runs again.
Every valid transition is a row in :data:`TRANSITIONS`; everything else
raises the typed :class:`InvalidTransition` — the exhaustiveness the tests
assert pair by pair.

The machine is pure data (no I/O): the journal (:mod:`repro.ctl.store`)
persists each applied transition, and replaying the journal through
:func:`transition` rebuilds the job table bit-for-bit.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Optional


class JobState(str, Enum):
    QUEUED = "queued"           # durable, waiting for admission
    ADMITTED = "admitted"       # admission control accepted; tenant built
    RUNNING = "running"         # attached to a device simulator
    MIGRATING = "migrating"     # launch queue draining toward another device
    DONE = "done"               # finished its work window (terminal)
    PREEMPTED = "preempted"     # evicted (drain/crash); resumable
    FAILED = "failed"           # malformed spec / runtime error (terminal)
    CANCELLED = "cancelled"     # user cancel (terminal)


class JobEvent(str, Enum):
    ADMIT = "admit"             # admission control accepts the job
    START = "start"             # client admitted into a simulator
    MIGRATE = "migrate"         # coordinator began draining the client
    LAND = "land"               # migration landed (or drain aborted)
    FINISH = "finish"           # work window complete, client detached
    PREEMPT = "preempt"         # evicted with intent to resume
    FAIL = "fail"               # unrecoverable error
    CANCEL = "cancel"           # user asked for the job to stop
    REQUEUE = "requeue"         # recovery/resume: back to the queue


#: Every legal ``(state, event) -> state`` row.  Anything absent raises.
TRANSITIONS: dict[tuple[JobState, JobEvent], JobState] = {
    (JobState.QUEUED, JobEvent.ADMIT): JobState.ADMITTED,
    (JobState.QUEUED, JobEvent.CANCEL): JobState.CANCELLED,
    (JobState.QUEUED, JobEvent.FAIL): JobState.FAILED,

    (JobState.ADMITTED, JobEvent.START): JobState.RUNNING,
    (JobState.ADMITTED, JobEvent.CANCEL): JobState.CANCELLED,
    (JobState.ADMITTED, JobEvent.PREEMPT): JobState.PREEMPTED,
    (JobState.ADMITTED, JobEvent.FAIL): JobState.FAILED,
    (JobState.ADMITTED, JobEvent.REQUEUE): JobState.QUEUED,

    (JobState.RUNNING, JobEvent.MIGRATE): JobState.MIGRATING,
    (JobState.RUNNING, JobEvent.FINISH): JobState.DONE,
    (JobState.RUNNING, JobEvent.CANCEL): JobState.CANCELLED,
    (JobState.RUNNING, JobEvent.PREEMPT): JobState.PREEMPTED,
    (JobState.RUNNING, JobEvent.FAIL): JobState.FAILED,
    (JobState.RUNNING, JobEvent.REQUEUE): JobState.QUEUED,

    (JobState.MIGRATING, JobEvent.LAND): JobState.RUNNING,
    (JobState.MIGRATING, JobEvent.FINISH): JobState.DONE,
    (JobState.MIGRATING, JobEvent.CANCEL): JobState.CANCELLED,
    (JobState.MIGRATING, JobEvent.PREEMPT): JobState.PREEMPTED,
    (JobState.MIGRATING, JobEvent.FAIL): JobState.FAILED,
    (JobState.MIGRATING, JobEvent.REQUEUE): JobState.QUEUED,

    (JobState.PREEMPTED, JobEvent.REQUEUE): JobState.QUEUED,
    (JobState.PREEMPTED, JobEvent.CANCEL): JobState.CANCELLED,
}

#: Absorbing states: no outgoing transitions, recovery leaves them alone.
TERMINAL = frozenset({JobState.DONE, JobState.FAILED, JobState.CANCELLED})


class InvalidTransition(Exception):
    """Raised for any (state, event) pair not in :data:`TRANSITIONS`."""

    def __init__(self, state: JobState, event: JobEvent):
        self.state = state
        self.event = event
        super().__init__(f"no transition for event {event.value!r} "
                         f"in state {state.value!r}")


def transition(state: JobState, event: JobEvent) -> JobState:
    """The next state, or raise :class:`InvalidTransition`."""
    try:
        return TRANSITIONS[(state, event)]
    except KeyError:
        raise InvalidTransition(state, event) from None


@dataclass
class Job:
    """Control-plane record of one submitted job.

    ``spec`` is the submission payload (workload description; see
    :func:`repro.ctl.daemon.app_from_spec`).  Data-plane bindings (``cid``,
    ``device``) are scoped to one daemon incarnation — a crash invalidates
    them and recovery re-admits the job with fresh ones."""

    job_id: str
    spec: dict
    state: JobState = JobState.QUEUED
    submitted_wall: float = field(default_factory=time.time)
    updated_wall: float = field(default_factory=time.time)
    # data-plane bindings (valid for the current daemon incarnation only)
    cid: Optional[int] = None
    device: Optional[int] = None
    granted_slices: int = 0
    admitted_sim: Optional[float] = None    # sim clock at START
    ends_sim: Optional[float] = None        # sim clock of the work window end
    # bookkeeping
    recoveries: int = 0                     # times re-queued by recovery
    migrations: int = 0
    error: str = ""
    result: dict = field(default_factory=dict)  # metrics stamped at FINISH

    def apply(self, event: JobEvent, wall: Optional[float] = None) -> JobState:
        """Apply one event through the state machine (raises
        :class:`InvalidTransition` on an illegal pair)."""
        self.state = transition(self.state, event)
        self.updated_wall = time.time() if wall is None else wall
        if event is JobEvent.REQUEUE:
            self.recoveries += 1
            self.cid = self.device = None
            self.granted_slices = 0
            self.admitted_sim = self.ends_sim = None
        if event is JobEvent.MIGRATE:
            self.migrations += 1
        return self.state

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL

    def public(self) -> dict:
        """The ``status`` view of this job (JSON-safe)."""
        return {
            "job_id": self.job_id,
            "name": self.spec.get("name", self.job_id),
            "state": self.state.value,
            "kind": self.spec.get("kind", "?"),
            "priority": self.spec.get("priority", "be"),
            "quota": self.spec.get("quota_slices", 0),
            "granted": self.granted_slices,
            "device": self.device,
            "cid": self.cid,
            "submitted_wall": self.submitted_wall,
            "updated_wall": self.updated_wall,
            "recoveries": self.recoveries,
            "migrations": self.migrations,
            "error": self.error,
            "result": self.result,
        }
