"""The always-on scheduler daemon: live admissions into the stepping API.

The batch world (``evaluate*``) builds every tenant before the clock
starts.  The daemon inverts that: it owns a :class:`NodeCoordinator` over
an (initially empty) multi-device node and *drives it event by event*
through the stepping API (``start / peek_time / step_event``), so jobs are
admitted, preempted, migrated and finished **while the clock advances**:

* **submit** (spool) -> journal ``SUBMIT`` -> ``QUEUED``;
* **admission control** reserves quota headroom on a device, then attaches
  the tenant live: grant pool slices (``SliceMap.assign_owner``), warm the
  policy (``import_client_state``), hand the simulator the client with its
  arrival stream re-based to the current sim clock (``admit_client``), and
  kick dispatch via the migration plumbing's ``hold``/``schedule_release``
  pair — ``QUEUED -> ADMITTED -> RUNNING``;
* **progress** is bounded stepping: the daemon only steps events up to the
  earliest active-job milestone, so simulated time never runs ahead of the
  control plane's decisions (and freezes entirely when the node is idle);
* **migration**: the coordinator's own lending protocol keeps working —
  the daemon observes ``_pending``/``migration_log`` and journals
  ``RUNNING -> MIGRATING -> RUNNING``;
* **finish/cancel/preempt** tear down through the drain half-protocol
  (hold -> drained -> disown granted slices -> export -> detach), then
  journal the terminal transition.

Every transition is journaled *before* the daemon acts on it (WAL), so
``kill -9`` at any instant is recoverable: on restart the journal replays,
non-terminal jobs are re-queued (``REQUEUE``) and re-admitted with fresh
data-plane bindings — no job lost, none duplicated.  Simulator state is
deliberately *not* checkpointed: the control plane is durable, the data
plane restarts (the job re-runs its remaining window), exactly the
contract a driver-level GPU control plane can honor.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.node import build_node
from repro.core.queues import Client
from repro.core.types import (DeviceSpec, FaultPlan, NodeConfig, NodeSpec,
                              Priority, Quota)
from repro.core.workloads import AppSpec
from repro.ctl import store
from repro.ctl.state import Job, JobEvent, JobState
from repro.ctl.store import Journal, replay

_INF = float("inf")


class JobSpecError(ValueError):
    """Submission payload that can never be admitted (``FAILED``)."""


DEVICE_PROFILES = {
    "a100": DeviceSpec.a100_like,
    "l4": DeviceSpec.l4_like,
    "tpu_v5e": DeviceSpec.tpu_v5e_pod_slice,
}


@dataclass(frozen=True)
class DaemonConfig:
    n_devices: int = 2
    device: str = "a100"            # DEVICE_PROFILES key
    n_slices: int = 0               # override slices per device (0 = profile)
    system: str = "lithos"
    engine: Optional[str] = None    # None -> repro.core.lithos.default_engine
    horizon: float = 1e9            # sim end event; never reached in practice
    seed: int = 0
    poll_interval: float = 0.02     # idle wall sleep between ticks
    max_steps_per_tick: int = 512   # stepping budget per tick (stays live)
    admit_cost: float = 0.0         # dispatch blackout charged at admission
    migration: bool = True          # node-level lending protocol on?
    epoch: float = 0.25             # pressure-sampling period
    validate: bool = False          # cross-device conservation checks
    heartbeat_interval: float = 0.2
    fault_plan: Optional[FaultPlan] = None  # injected device/slice failures
    compact_threshold_bytes: int = 512 * 1024   # journal size trigger (0=off)

    def node(self) -> NodeSpec:
        if self.device not in DEVICE_PROFILES:
            raise ValueError(f"unknown device profile {self.device!r} "
                             f"(choose from {sorted(DEVICE_PROFILES)})")
        dev = DEVICE_PROFILES[self.device]()
        if self.n_slices > 0:
            dev = dataclasses.replace(dev, n_slices=self.n_slices)
        return NodeSpec.uniform(self.n_devices, dev)


def app_from_spec(spec: dict, *, fallback_name: str) -> tuple[AppSpec, float]:
    """Submission payload -> (tenant AppSpec, work-window duration).

    ``kind == "serve"`` is the SlotServer client (``launch/serve.py
    --submit``): it becomes an open-loop ``llm_infer`` tenant carrying its
    SLO class and quota — the serving engine's request stream expressed in
    the simulator's workload vocabulary."""
    from repro.configs.registry import ARCH_IDS, get_config

    kind = spec.get("kind", "train")
    sim_kind = {"serve": "llm_infer"}.get(kind, kind)
    if sim_kind not in ("train", "llm_infer", "fwd_infer"):
        raise JobSpecError(f"unknown job kind {kind!r}")
    arch = spec.get("arch", "olmo-1b")
    if arch not in ARCH_IDS:
        raise JobSpecError(f"unknown arch {arch!r}")
    cfg = get_config(arch)
    if spec.get("reduced", True):
        cfg = cfg.reduced()
    prio = str(spec.get("priority", "be")).lower()
    if prio in ("high", "hp"):
        priority = Priority.HIGH
    elif prio in ("be", "best_effort", "low"):
        priority = Priority.BEST_EFFORT
    else:
        raise JobSpecError(f"unknown priority {prio!r}")
    duration = float(spec.get("duration", 5.0))
    if not duration > 0:
        raise JobSpecError(f"duration must be > 0, got {duration}")
    quota = int(spec.get("quota_slices", 0))
    if quota < 0:
        raise JobSpecError(f"quota_slices must be >= 0, got {quota}")
    rps = float(spec.get("rps", 0.0))
    if sim_kind != "train" and rps <= 0:
        raise JobSpecError(f"open-loop kind {kind!r} needs rps > 0")
    kw = {}
    if "prompt_mix" in spec:
        kw["prompt_mix"] = tuple((int(l), float(w))
                                 for l, w in spec["prompt_mix"])
    app = AppSpec(
        name=spec.get("name", fallback_name), cfg=cfg, kind=sim_kind,
        priority=priority, quota_slices=quota,
        rps=rps if sim_kind != "train" else 0.0,
        slo_latency=float(spec.get("slo_latency", 0.0)),
        batch=int(spec.get("batch", 1)),
        decode_tokens=int(spec.get("decode_tokens", 16)),
        train_batch=int(spec.get("train_batch", 2)),
        train_seq=int(spec.get("train_seq", 256)),
        fusion=int(spec.get("fusion", 6)),
        seed=int(spec.get("seed", 0)), **kw)
    return app, duration


@dataclass
class _Runtime:
    """Data-plane bindings of one live job (one daemon incarnation)."""

    job: Job
    cid: int
    want_quota: int
    t0: float                       # sim clock at admission
    t_end: float                    # t0 + duration
    last_arrival: float             # sim time of the final seeded arrival
    closed_loop: bool
    granted: list[int] = field(default_factory=list)   # sids, home device
    teardown: Optional[JobEvent] = None     # FINISH/CANCEL/PREEMPT pending
    result: dict = field(default_factory=dict)

    @property
    def milestone(self) -> float:
        """Sim time up to which this job still wants the clock to advance
        (the stepping bound).  Draining jobs and open-loop tails are
        unbounded — their remaining events are finite."""
        if self.teardown is not None or not self.closed_loop:
            return _INF
        return self.t_end


class ControlPlane:
    """One daemon incarnation: journal + job table + live node."""

    def __init__(self, state_dir: str, config: Optional[DaemonConfig] = None):
        from repro.core.lithos import default_engine

        self.state_dir = state_dir
        self.cfg = config or DaemonConfig()
        self.journal = Journal(state_dir)
        self.jobs: dict[str, Job] = replay(state_dir)
        self.node = self.cfg.node()
        engine = self.cfg.engine or default_engine()
        self.coord = build_node(
            self.cfg.system, self.node, [], [], horizon=self.cfg.horizon,
            seed=self.cfg.seed, engine=engine,
            node_config=NodeConfig(migration=self.cfg.migration,
                                   epoch=self.cfg.epoch,
                                   validate=self.cfg.validate),
            faults=self.cfg.fault_plan)
        # the daemon owns fault handling: jobs on a dead device take the
        # journaled PREEMPT -> REQUEUE path and re-admit onto surviving
        # capacity, instead of the coordinator's in-sim evacuation
        self.coord.auto_evacuate = False
        self._dead: set[int] = set()
        self.coord.start()
        self._rt: dict[str, _Runtime] = {}
        self._by_cid: dict[int, str] = {}
        self._reserved: list[dict[str, int]] = [
            {} for _ in range(self.node.n_devices)]   # device -> job -> want
        self._mig_seen = 0
        self._draining = False
        self._stop = False
        self._last_hb = 0.0
        self.started_wall = time.time()
        # fresh incarnation: old data-plane bindings are void
        self.next_cid = 1 + max((j.cid for j in self.jobs.values()
                                 if j.cid is not None), default=-1)
        self._recover()
        store.clear_drain(state_dir)
        # announce liveness before any admission can hit the journal —
        # `status` must never see RUNNING jobs with no heartbeat on disk
        self._heartbeat(force=True)

    # -- recovery ------------------------------------------------------------

    def _recover(self):
        """Re-queue every job the previous incarnation left non-terminal.
        QUEUED jobs are already where they belong; ADMITTED/RUNNING/
        MIGRATING lost their simulator with the crash, PREEMPTED is the
        graceful-drain parking state — all four resume via REQUEUE."""
        for job in sorted(self.jobs.values(), key=lambda j: j.submitted_wall):
            if job.state in (JobState.ADMITTED, JobState.RUNNING,
                             JobState.MIGRATING, JobState.PREEMPTED):
                self._event(job, JobEvent.REQUEUE)

    # -- journal-backed transitions ------------------------------------------

    def _event(self, job: Job, ev: JobEvent, **extra):
        """WAL discipline: validate, journal durably, then mutate."""
        from repro.ctl.state import transition
        to = transition(job.state, ev)          # raises on an illegal pair
        self.journal.append(job.job_id, ev.value, to=to.value, **extra)
        job.apply(ev)
        for k in ("cid", "device", "admitted_sim", "ends_sim"):
            if k in extra:
                setattr(job, k, extra[k])
        if "granted" in extra:
            job.granted_slices = extra["granted"]
        if "error" in extra:
            job.error = extra["error"]
        if "result" in extra:
            job.result = extra["result"]

    # -- clock ---------------------------------------------------------------

    def sim_now(self) -> float:
        return max(s.now for s in self.coord.sims)

    # -- inbox ---------------------------------------------------------------

    def _ingest(self):
        submits, cancels, drain, rejected = store.scan_inbox(self.state_dir)
        for r in rejected:
            # the file is already quarantined in inbox/rejected/; if the
            # filename still identifies a submit's job id, record the loss
            # so the submitter sees FAILED instead of a job that vanished
            jid = r.get("job_id")
            if jid and r["kind"] == "submit" and jid not in self.jobs:
                self.journal.append(jid, store.SUBMIT, spec={},
                                    to=JobState.QUEUED.value)
                job = Job(job_id=jid, spec={})
                self.jobs[jid] = job
                self._event(job, JobEvent.FAIL,
                            error=f"rejected spool file: {r['reason']}")
        for s in submits:
            jid = s["job_id"]
            if jid not in self.jobs:        # crash between journal+unlink:
                self.journal.append(jid, store.SUBMIT, spec=s["spec"],
                                    to=JobState.QUEUED.value)
                self.jobs[jid] = Job(job_id=jid, spec=s["spec"])
            store.consume(s)
        for c in cancels:
            job = self.jobs.get(c["job_id"])
            if job is None:
                continue                    # not ingested yet: retry later
            if not job.terminal:
                self._cancel(job)
            store.consume(c)
        if drain and not self._draining:
            self._draining = True
            for job in list(self.jobs.values()):
                rt = self._rt.get(job.job_id)
                if rt is not None and rt.teardown is None:
                    self._begin_teardown(rt, JobEvent.PREEMPT)
                elif job.state == JobState.ADMITTED and rt is None:
                    self._event(job, JobEvent.PREEMPT)

    def _cancel(self, job: Job):
        rt = self._rt.get(job.job_id)
        if rt is None:
            # not attached: pure control-plane transition
            self._event(job, JobEvent.CANCEL)
            self._unreserve(job.job_id)
        elif rt.teardown is None:
            self._begin_teardown(rt, JobEvent.CANCEL)

    # -- admission -----------------------------------------------------------

    def _headroom(self, d: int) -> int:
        return (self.node.devices[d].n_slices
                - getattr(self.coord.sims[d], "n_retired", 0)
                - sum(self._reserved[d].values()))

    def _pick_device(self, want: int) -> Optional[int]:
        fits = [d for d in range(self.node.n_devices)
                if d not in self._dead and self._headroom(d) >= want]
        if not fits:
            return None
        # fewest live jobs first, then most headroom — deterministic
        return min(fits, key=lambda d: (len(self._reserved[d]),
                                        -self._headroom(d), d))

    def _unreserve(self, job_id: str):
        for res in self._reserved:
            res.pop(job_id, None)

    def _admit_queued(self):
        if self._draining:
            return
        queued = [j for j in self.jobs.values()
                  if j.state == JobState.QUEUED]
        for job in sorted(queued, key=lambda j: (j.submitted_wall, j.job_id)):
            try:
                app, duration = app_from_spec(job.spec,
                                              fallback_name=job.job_id)
            except JobSpecError as e:
                self._event(job, JobEvent.FAIL, error=str(e))
                continue
            if app.kind == "train" and not getattr(
                    self.coord.policies[0], "supports_migration", False):
                # closed-loop tenants never drain on their own; without the
                # hold/drain half-protocol the daemon could not stop them
                self._event(job, JobEvent.FAIL,
                            error=f"system {self.cfg.system!r} cannot "
                                  "preempt closed-loop (train) jobs")
                continue
            want = min(app.quota_slices,
                       max(d.n_slices for d in self.node.devices))
            if want < app.quota_slices and job.spec.get("strict_quota"):
                self._event(job, JobEvent.FAIL,
                            error=f"quota {app.quota_slices} exceeds every "
                                  f"device ({want} max)")
                continue
            d = self._pick_device(want)
            if d is None:
                continue                    # wait for headroom
            cid = self.next_cid
            self.next_cid += 1
            self._reserved[d][job.job_id] = want
            self._event(job, JobEvent.ADMIT, cid=cid, device=d)
            self._attach(job, app, duration, cid, d, want)

    def _attach(self, job: Job, app: AppSpec, duration: float, cid: int,
                d: int, want: int):
        sim = self.coord.sims[d]
        policy = self.coord.policies[d]
        t0 = self.sim_now()
        granted = self._grant(policy, cid, want)
        policy.import_client_state(cid, app.priority,
                                   {"quota": Quota(len(granted),
                                                   app.priority)})
        client = Client(cid, app, horizon=duration, seed=self.cfg.seed)
        client._arrivals = [t0 + a for a in client._arrivals]
        last_arrival = client._arrivals[-1] if client._arrivals else -_INF
        policy.hold_client(cid)
        sim.admit_client(client, after=t0)
        sim.schedule_release(cid, t0 + self.cfg.admit_cost)
        self.coord.ledger.adopt(cid, d)
        self.coord._dirty_deep(d)
        rt = _Runtime(job=job, cid=cid, want_quota=want, t0=t0,
                      t_end=t0 + duration, last_arrival=last_arrival,
                      closed_loop=client.closed_loop, granted=granted)
        self._rt[job.job_id] = rt
        self._by_cid[cid] = job.job_id
        self._event(job, JobEvent.START, granted=len(granted),
                    admitted_sim=t0, ends_sim=rt.t_end)

    def _grant(self, policy, cid: int, want: int) -> list[int]:
        sm = getattr(policy, "slices", None)
        if sm is None or want <= 0:
            return []
        sids = sm.idle_pool()[:want]
        for sid in sids:
            sm.assign_owner(sid, cid)
        return list(sids)

    def _topup(self, rt: _Runtime):
        """Admission reserved the full quota; the instant of the grant may
        have found part of the pool held by in-flight kernels.  Top the
        grant up as pool slices free."""
        if rt.teardown is not None or len(rt.granted) >= rt.want_quota:
            return
        job = rt.job
        d = self.coord.ledger.current.get(rt.cid, job.device)
        policy = self.coord.policies[d]
        more = self._grant(policy, rt.cid,
                           rt.want_quota - len(rt.granted))
        if more:
            rt.granted += more
            quotas = getattr(policy, "quotas", None)
            q = quotas.get(rt.cid) if quotas is not None else None
            if q is not None:
                quotas[rt.cid] = Quota(len(rt.granted), q.priority)
            job.granted_slices = len(rt.granted)

    # -- stepping ------------------------------------------------------------

    def _bound(self) -> float:
        if not self._rt:
            return -_INF
        return min(rt.milestone for rt in self._rt.values())

    def _step(self) -> int:
        # never step the end-of-horizon sentinel events: with an unbounded
        # milestone (open-loop tails, teardown drains) they would yank the
        # clock to ``horizon`` and the coordinator's epoch catch-up loop
        # would grind through billions of empty epochs
        bound = min(self._bound(), self.cfg.horizon * (1 - 1e-9))
        steps = 0
        while steps < self.cfg.max_steps_per_tick:
            t = self.coord.peek_time()
            if t is None or t > bound:
                break
            if not self.coord.step_event():
                break
            steps += 1
        return steps

    # -- migration observation ----------------------------------------------

    def _observe_migrations(self):
        log = self.coord.migration_log
        while self._mig_seen < len(log):
            _, cid, _, dst = log[self._mig_seen]
            self._mig_seen += 1
            jid = self._by_cid.get(cid)
            job = self.jobs.get(jid) if jid else None
            if job is None:
                continue
            if job.state == JobState.RUNNING:    # missed the pending window
                self._event(job, JobEvent.MIGRATE)
            if job.state == JobState.MIGRATING:
                self._event(job, JobEvent.LAND, device=dst)
        pending = self.coord._pending
        if pending is not None:
            jid = self._by_cid.get(pending.cid)
            job = self.jobs.get(jid) if jid else None
            if job is not None and job.state == JobState.RUNNING:
                self._event(job, JobEvent.MIGRATE)
        for jid, rt in self._rt.items():
            job = rt.job
            if job.state == JobState.MIGRATING and (
                    pending is None or pending.cid != rt.cid):
                # drain aborted (e.g. horizon/dead) — land back in place
                self._event(job, JobEvent.LAND, device=job.device)

    # -- fault observation ---------------------------------------------------

    def _observe_faults(self):
        """Map device loss onto the job state machine: every job bound to a
        newly failed device is detached from the dead data plane, journaled
        ``PREEMPT`` (with a fault record naming the device) then
        ``REQUEUE``, and re-admitted onto surviving capacity by the normal
        admission pass — never silently lost.  A cancel already in flight
        wins over the requeue."""
        for d in sorted(self.coord.failed_members - self._dead):
            self._dead.add(d)
            lost = sorted(
                jid for jid, rt in self._rt.items()
                if self.coord.ledger.current.get(rt.cid, rt.job.device) == d)
            # standalone fault record: replay/compact pass it through (its
            # job id never matches a real job), so the loss stays on the
            # permanent record even after the jobs finish elsewhere
            self.journal.append(f"device-{d}", "fault", device=d,
                                sim_now=self.sim_now(), jobs=lost)
            for jid in lost:
                rt = self._rt.pop(jid)
                job, cid = rt.job, rt.cid
                sim = self.coord.sims[d]
                # the device's own scheduler already killed its in-flight
                # work (Policy.on_fault); here we retire the control-plane
                # bindings.  Ownership may be spread across devices after a
                # migration, so sweep every live slice map.
                for p in self.coord.policies:
                    sm = getattr(p, "slices", None)
                    if sm is None:
                        continue
                    for sid in rt.granted:
                        if (sid < sm.n_slices and sm.owner[sid] == cid
                                and sm.holder[sid] is None):
                            sm.disown(sid)
                policy = self.coord.policies[d]
                if cid in getattr(policy, "quotas", ()):
                    policy.export_client_state(cid)     # discard: dead plane
                sim.detach_client(cid)
                self.coord.ledger.drop(cid, sim.now)
                self.coord._dirty_deep(d)
                self.coord.frozen.discard(cid)
                self._by_cid.pop(cid, None)
                self._unreserve(jid)
                if rt.teardown == JobEvent.CANCEL:
                    self._event(job, JobEvent.CANCEL,
                                fault={"device": d, "sim_now": sim.now})
                    continue
                self._event(job, JobEvent.PREEMPT,
                            fault={"device": d, "sim_now": sim.now})
                self._event(job, JobEvent.REQUEUE)

    # -- teardown / reaping --------------------------------------------------

    def _begin_teardown(self, rt: _Runtime, reason: JobEvent):
        rt.teardown = reason
        self.coord.frozen.add(rt.cid)       # keep the lender's hands off
        d = self.coord.ledger.current.get(rt.cid, rt.job.device)
        self.coord.policies[d].hold_client(rt.cid)

    def _reap(self):
        for jid, rt in list(self._rt.items()):
            job = rt.job
            if job.state == JobState.MIGRATING:
                continue                    # finish the move first
            d = self.coord.ledger.current.get(rt.cid, job.device)
            sim = self.coord.sims[d]
            policy = self.coord.policies[d]
            if rt.teardown is None and self._window_over(rt, sim):
                self._begin_teardown(rt, JobEvent.FINISH)
            if rt.teardown is None:
                self._topup(rt)
                continue
            if not policy.client_drained(rt.cid):
                continue
            sm = getattr(policy, "slices", None)
            if sm is not None and any(sm.holder[s] is not None
                                      for s in rt.granted):
                continue                    # a thief still holds a grant
            self._detach(rt, d, sim, policy, sm)

    def _window_over(self, rt: _Runtime, sim) -> bool:
        """Nothing left inside this job's work window: for closed loops the
        clock (or the next event) passed ``t_end``; for open loops every
        seeded arrival fired and the launch queue drained."""
        peek = sim.peek_time()
        if rt.closed_loop:
            return sim.now >= rt.t_end or peek is None or peek > rt.t_end
        arrivals_done = (sim.now >= rt.last_arrival or peek is None
                         or peek > rt.last_arrival)
        c = sim.client_by_id.get(rt.cid)
        drained = (c is not None and c.outstanding == 0
                   and c.current is None and not c.pending)
        return arrivals_done and drained and sim.now >= rt.t0

    def _detach(self, rt: _Runtime, d: int, sim, policy, sm):
        cid, job = rt.cid, rt.job
        for sid in rt.granted:
            sm.disown(sid)
        policy.export_client_state(cid)     # discard: the job is over
        client = sim.detach_client(cid)
        self.coord.ledger.drop(cid, sim.now)
        self.coord._dirty_deep(d)
        self.coord.frozen.discard(cid)
        self._rt.pop(job.job_id)
        self._by_cid.pop(cid, None)
        self._unreserve(job.job_id)
        lats = client.latencies()
        result = {
            "n_completed": len(client.completed),
            "sim_seconds": round(sim.now - rt.t0, 6),
            "slice_seconds": round(client.slice_seconds, 6),
            "p50_ms": round(1e3 * float(np.median(lats)), 3) if lats else None,
            "p95_ms": (round(1e3 * float(np.percentile(lats, 95)), 3)
                       if lats else None),
        }
        rt.result = result
        self._event(job, rt.teardown, result=result)

    # -- heartbeat / status --------------------------------------------------

    def _heartbeat(self, force: bool = False):
        now = time.time()
        if not force and now - self._last_hb < self.cfg.heartbeat_interval:
            return
        self._last_hb = now
        counts: dict[str, int] = {}
        for j in self.jobs.values():
            counts[j.state.value] = counts.get(j.state.value, 0) + 1
        store.write_heartbeat(self.state_dir, {
            "sim_now": self.sim_now(),
            "events": sum(s.events for s in self.coord.sims),
            "jobs": counts,
            "live": len(self._rt),
            "draining": self._draining,
            "started_wall": self.started_wall,
            "migrations": self.coord.ledger.n_migrations,
        })

    # -- main loop -----------------------------------------------------------

    def tick(self) -> int:
        """One control-plane iteration; returns events stepped (progress
        indicator for the caller's sleep decision)."""
        self._ingest()
        self._admit_queued()
        stepped = self._step()
        self._observe_migrations()
        self._observe_faults()
        self._reap()
        self._heartbeat()
        self._maybe_compact()
        return stepped

    def _maybe_compact(self):
        """Bound journal growth: when the file crosses the size threshold,
        collapse terminal jobs' histories to snapshots (atomic rewrite) and
        reopen the journal at the renumbered tail."""
        if self.cfg.compact_threshold_bytes <= 0:
            return
        try:
            size = os.path.getsize(self.journal.path)
        except OSError:
            return
        if size < self.cfg.compact_threshold_bytes:
            return
        self.journal.close()
        store.compact(self.state_dir)
        self.journal = Journal(self.state_dir)

    def idle(self) -> bool:
        """True when there is nothing to do but wait for the spool."""
        return not self._rt and not any(
            j.state == JobState.QUEUED for j in self.jobs.values())

    def stop(self):
        self._stop = True

    def install_signal_handlers(self):
        signal.signal(signal.SIGTERM, lambda *_: self.stop())
        signal.signal(signal.SIGINT, lambda *_: self.stop())

    def run(self, max_wall: Optional[float] = None,
            exit_when_idle: bool = False):
        t0 = time.time()
        try:
            while not self._stop:
                stepped = self.tick()
                if self._draining and not self._rt:
                    break                   # drained: graceful exit
                if max_wall is not None and time.time() - t0 > max_wall:
                    break
                if exit_when_idle and self.idle():
                    submits, cancels, _, _ = store.scan_inbox(self.state_dir)
                    if not submits and not cancels:
                        break
                if stepped == 0:
                    time.sleep(self.cfg.poll_interval)
        finally:
            self.shutdown()

    def shutdown(self):
        """Graceful exit: park still-live jobs as PREEMPTED (resumable on
        the next incarnation); queued jobs just stay queued."""
        for jid, rt in list(self._rt.items()):
            job = rt.job
            if job.state == JobState.MIGRATING:
                self._event(job, JobEvent.PREEMPT)
            elif job.state in (JobState.RUNNING, JobState.ADMITTED):
                self._event(job, JobEvent.PREEMPT)
        self._rt.clear()
        self._by_cid.clear()
        self._heartbeat(force=True)
        self.journal.close()
