"""``python -m repro.ctl`` — submit / status / cancel / drain / daemon.

The CLI never imports the simulator stack except for the ``daemon`` verb:
``submit``/``cancel``/``drain`` only touch the spool, and ``status`` only
replays the journal, so they work (fast, jax-free) whether or not a daemon
is running — and against the state dir of a *crashed* daemon, which is how
operators inspect what recovery will do before restarting.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.ctl import store
from repro.ctl.state import TERMINAL, JobState


def _add_state_dir(p: argparse.ArgumentParser):
    p.add_argument("--state-dir", required=True,
                   help="control-plane state directory (journal + inbox)")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.ctl",
        description="online serving control plane")
    sub = ap.add_subparsers(dest="verb", required=True)

    d = sub.add_parser("daemon", help="run the scheduler daemon")
    _add_state_dir(d)
    d.add_argument("--devices", type=int, default=2)
    d.add_argument("--device", default="a100",
                   help="device profile: a100 | l4 | tpu_v5e")
    d.add_argument("--slices", type=int, default=0,
                   help="override slices per device (0 = profile default)")
    d.add_argument("--system", default="lithos")
    d.add_argument("--engine", default=None, help="ref | vec")
    d.add_argument("--seed", type=int, default=0)
    d.add_argument("--poll", type=float, default=0.02)
    d.add_argument("--no-migration", action="store_true")
    d.add_argument("--validate", action="store_true")
    d.add_argument("--max-wall", type=float, default=None,
                   help="exit after this many wall seconds")
    d.add_argument("--exit-when-idle", action="store_true",
                   help="exit once no queued or live jobs remain")

    s = sub.add_parser("submit", help="queue a job")
    _add_state_dir(s)
    s.add_argument("--kind", default="train",
                   choices=["train", "serve", "llm_infer", "fwd_infer"])
    s.add_argument("--arch", default="olmo-1b")
    s.add_argument("--name", default=None)
    s.add_argument("--priority", default="be", choices=["be", "hp", "high"])
    s.add_argument("--quota", type=int, default=0,
                   help="pinned TPC slices (admission-controlled)")
    s.add_argument("--rps", type=float, default=0.0)
    s.add_argument("--duration", type=float, default=5.0,
                   help="work window in simulated seconds")
    s.add_argument("--slo", type=float, default=0.0,
                   help="SLO latency (seconds) for serve jobs")
    s.add_argument("--batch", type=int, default=1)
    s.add_argument("--decode-tokens", type=int, default=16)
    s.add_argument("--seed", type=int, default=0)
    s.add_argument("--full-size", action="store_true",
                   help="use the full (non-reduced) model config")
    s.add_argument("--spec-json", default=None,
                   help="raw spec JSON; overrides the flags above")
    s.add_argument("--wait", action="store_true",
                   help="block until the job reaches a terminal state")
    s.add_argument("--timeout", type=float, default=120.0,
                   help="--wait timeout (wall seconds)")

    st = sub.add_parser("status", help="show job table (journal replay)")
    _add_state_dir(st)
    st.add_argument("job_id", nargs="?", default=None)
    st.add_argument("--json", action="store_true")

    c = sub.add_parser("cancel", help="cancel a job")
    _add_state_dir(c)
    c.add_argument("job_id")

    dr = sub.add_parser("drain", help="preempt live jobs and stop the daemon")
    _add_state_dir(dr)
    return ap


def _verb_daemon(args) -> int:
    from repro.ctl.daemon import ControlPlane, DaemonConfig
    cp = ControlPlane(args.state_dir, DaemonConfig(
        n_devices=args.devices, device=args.device, n_slices=args.slices,
        system=args.system, engine=args.engine, seed=args.seed,
        poll_interval=args.poll, migration=not args.no_migration,
        validate=args.validate))
    cp.install_signal_handlers()
    print(f"ctl daemon pid={__import__('os').getpid()} "
          f"state_dir={cp.state_dir} devices={cp.node.n_devices} "
          f"recovered={sum(1 for j in cp.jobs.values() if j.recoveries)}",
          flush=True)
    cp.run(max_wall=args.max_wall, exit_when_idle=args.exit_when_idle)
    return 0


def _verb_submit(args) -> int:
    if args.spec_json:
        spec = json.loads(args.spec_json)
    else:
        spec = {"kind": args.kind, "arch": args.arch,
                "priority": args.priority, "quota_slices": args.quota,
                "rps": args.rps, "duration": args.duration,
                "slo_latency": args.slo, "batch": args.batch,
                "decode_tokens": args.decode_tokens, "seed": args.seed,
                "reduced": not args.full_size}
        if args.name:
            spec["name"] = args.name
    jid = store.request_submit(args.state_dir, spec)
    print(jid, flush=True)
    if not args.wait:
        return 0
    deadline = time.time() + args.timeout
    while time.time() < deadline:
        job = store.replay(args.state_dir).get(jid)
        if job is not None and job.state in TERMINAL:
            print(json.dumps(job.public(), indent=2))
            return 0 if job.state is JobState.DONE else 1
        time.sleep(0.1)
    print(f"timeout: {jid} not terminal after {args.timeout}s",
          file=sys.stderr)
    return 2


def _verb_status(args) -> int:
    jobs = store.replay(args.state_dir)
    hb = store.read_heartbeat(args.state_dir)
    if args.job_id is not None:
        job = jobs.get(args.job_id)
        if job is None:
            print(f"no such job: {args.job_id}", file=sys.stderr)
            return 1
        print(json.dumps(job.public(), indent=2))
        return 0
    table = [j.public() for j in
             sorted(jobs.values(), key=lambda j: j.submitted_wall)]
    if args.json:
        print(json.dumps({"daemon": hb, "jobs": table}, indent=2))
        return 0
    if hb is None:
        print("daemon: never ran here")
    else:
        state = "alive" if hb.get("alive") else "down"
        print(f"daemon: {state} pid={hb.get('pid')} "
              f"sim_now={hb.get('sim_now', 0):.3f} "
              f"events={hb.get('events', 0)}")
    fmt = "{:<18} {:<10} {:>4} {:>6}/{:<6} {:>6} {:>4} {:>4}  {}"
    print(fmt.format("JOB", "STATE", "DEV", "GRANT", "QUOTA",
                     "DONE", "RQ", "MIG", "NAME"))
    for row in table:
        res = row["result"] or {}
        print(fmt.format(
            row["job_id"][:18], row["state"],
            "-" if row["device"] is None else row["device"],
            row["granted"], row["quota"],
            res.get("n_completed", "-"), row["recoveries"],
            row["migrations"], row["name"]))
    return 0


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.verb == "daemon":
        return _verb_daemon(args)
    if args.verb == "submit":
        return _verb_submit(args)
    if args.verb == "status":
        return _verb_status(args)
    if args.verb == "cancel":
        store.request_cancel(args.state_dir, args.job_id)
        print(f"cancel requested: {args.job_id}")
        return 0
    if args.verb == "drain":
        store.request_drain(args.state_dir)
        print("drain requested")
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
