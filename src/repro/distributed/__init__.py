from repro.distributed.coordinator import (Coordinator, CoordinatorConfig,
                                           HostState)
from repro.distributed.elastic import elastic_mesh_shapes, shrink_mesh
