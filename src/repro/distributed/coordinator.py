"""Fault-tolerance coordinator: heartbeats, failure detection, straggler
mitigation, and restart orchestration.

At datacenter scale (1000+ hosts) the coordinator is the control-plane
counterpart of LithOS's device scheduler: it watches per-host liveness and
per-step timing, and drives the recovery state machine:

    HEALTHY -> (missed heartbeats) -> SUSPECT -> (timeout) -> FAILED
      -> shrink the data axis (elastic.py) -> restore latest checkpoint
      -> resume

Straggler mitigation mirrors the paper's TPC-stealing philosophy at the
pod level: hosts whose step times exceed ``straggler_factor`` x the fleet
median get their best-effort colocated work throttled first (hook), and are
excluded from the critical path by rebalancing if they persist.

The coordinator is deliberately transport-agnostic: ``heartbeat()`` /
``report_step()`` are called by the training driver (launch/train.py); in a
real deployment they arrive over RPC, in tests they are called directly
with a simulated clock.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Optional


class HostState(Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    FAILED = "failed"
    STRAGGLER = "straggler"


@dataclass
class CoordinatorConfig:
    heartbeat_interval: float = 5.0
    suspect_after: float = 15.0          # missed-heartbeat window
    fail_after: float = 45.0
    straggler_factor: float = 1.5
    straggler_window: int = 8            # steps of history per host
    min_hosts: int = 1


@dataclass
class _Host:
    hid: int
    last_beat: float = 0.0
    state: HostState = HostState.HEALTHY
    step_times: list[float] = field(default_factory=list)


class Coordinator:
    def __init__(self, n_hosts: int, config: CoordinatorConfig = CoordinatorConfig(),
                 clock: Optional[Callable[[], float]] = None):
        self.cfg = config
        self.clock = clock or time.monotonic
        now = self.clock()
        self.hosts = {h: _Host(h, last_beat=now) for h in range(n_hosts)}
        self.events: list[tuple[float, str, int]] = []
        # callbacks wired by the driver
        self.on_fail: Optional[Callable[[list[int]], None]] = None
        self.on_straggler: Optional[Callable[[int], None]] = None

    # -- inputs ----------------------------------------------------------------

    def heartbeat(self, hid: int):
        h = self.hosts[hid]
        h.last_beat = self.clock()
        if h.state == HostState.SUSPECT:
            h.state = HostState.HEALTHY
            self.events.append((h.last_beat, "recovered", hid))

    def report_step(self, hid: int, step_seconds: float):
        h = self.hosts[hid]
        h.step_times.append(step_seconds)
        if len(h.step_times) > self.cfg.straggler_window:
            h.step_times.pop(0)
        self.heartbeat(hid)

    # -- evaluation --------------------------------------------------------------

    def alive(self) -> list[int]:
        return [h.hid for h in self.hosts.values()
                if h.state != HostState.FAILED]

    def check(self) -> dict[int, HostState]:
        """Advance the liveness/straggler state machine; fire callbacks."""
        now = self.clock()
        newly_failed = []
        for h in self.hosts.values():
            if h.state == HostState.FAILED:
                continue
            silent = now - h.last_beat
            if silent > self.cfg.fail_after:
                h.state = HostState.FAILED
                newly_failed.append(h.hid)
                self.events.append((now, "failed", h.hid))
            elif silent > self.cfg.suspect_after:
                if h.state != HostState.SUSPECT:
                    self.events.append((now, "suspect", h.hid))
                h.state = HostState.SUSPECT
        if newly_failed and self.on_fail:
            self.on_fail(newly_failed)
        self._check_stragglers(now)
        return {h.hid: h.state for h in self.hosts.values()}

    def _check_stragglers(self, now: float):
        samples = {h.hid: statistics.median(h.step_times)
                   for h in self.hosts.values()
                   if h.state in (HostState.HEALTHY, HostState.STRAGGLER)
                   and len(h.step_times) >= 3}
        if len(samples) < 2:
            return
        med = statistics.median(samples.values())
        for hid, t in samples.items():
            h = self.hosts[hid]
            if t > self.cfg.straggler_factor * med:
                if h.state != HostState.STRAGGLER:
                    h.state = HostState.STRAGGLER
                    self.events.append((now, "straggler", hid))
                    if self.on_straggler:
                        self.on_straggler(hid)
            elif h.state == HostState.STRAGGLER:
                h.state = HostState.HEALTHY
                self.events.append((now, "destraggled", hid))

    def fleet_ok(self) -> bool:
        return len(self.alive()) >= self.cfg.min_hosts
