"""Elastic mesh management: shrink/grow the data axis on host failure.

Model-parallel shards are the unit of survival: losing a host removes one
or more full data-parallel replicas (the ``model`` axis must stay intact, so
we drop the whole data rows containing failed hosts).  ``shrink_mesh``
computes the largest valid mesh from the surviving device set; the driver
then restores the latest checkpoint onto the new mesh (checkpoint/ is
mesh-independent) and resumes.

On real pods the device set comes from ``jax.devices()`` after the runtime
re-initializes; in tests we pass explicit device lists.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def elastic_mesh_shapes(n_devices: int, model_parallel: int,
                        pods: int = 1) -> Optional[tuple[int, ...]]:
    """Largest (pod, data, model) / (data, model) shape fitting n_devices.

    The model axis is fixed (parameter shards must stay whole); the data
    axis absorbs the loss.  Returns None if not even one replica fits.
    """
    per_pod = n_devices // pods
    data = per_pod // model_parallel
    if data < 1:
        return None
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)


def shrink_mesh(devices: Sequence, model_parallel: int,
                axis_names: tuple[str, ...] = ("data", "model")
                ) -> Optional[Mesh]:
    """Build the largest valid mesh from surviving devices.

    Drops the remainder so every data row has a full ``model_parallel``
    worth of devices."""
    n = len(devices)
    data = n // model_parallel
    if data < 1:
        return None
    usable = np.array(devices[:data * model_parallel]).reshape(
        data, model_parallel)
    return Mesh(usable, axis_names)


def survivors(devices: Sequence, failed_hosts: Sequence[int],
              devices_per_host: int) -> list:
    """Device list with failed hosts' devices removed (host h owns the
    contiguous block [h*dph, (h+1)*dph))."""
    failed = set(failed_hosts)
    return [d for i, d in enumerate(devices)
            if i // devices_per_host not in failed]
