"""HLO collective parser: per-device communication bytes from compiled HLO.

``cost_analysis()`` does not report collective traffic, so we parse the
post-optimization HLO text and sum the bytes each collective moves over the
interconnect, per device, using standard ring-algorithm accounting:

    all-gather        result_bytes * (g-1)/g      (receives g-1 shards)
    reduce-scatter    operand_bytes * (g-1)/g
    all-reduce        2 * bytes * (g-1)/g         (RS + AG)
    all-to-all        bytes * (g-1)/g
    collective-permute  bytes                      (one hop send)

where g is the replica-group size parsed from ``replica_groups``.  Shapes in
post-SPMD HLO are already per-device, so results are per-device bytes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> float:
    """Sum bytes over a (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))        # [n_groups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1)
        return max(1, len([x for x in first.split(",") if x.strip() != ""]))
    return 2


@dataclass
class CollectiveOp:
    kind: str
    bytes_moved: float                 # per device, over the interconnect
    result_bytes: float
    group_size: int


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        rb = _shape_bytes(type_str)
        g = _group_size(line)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            moved = rb * frac
        elif kind == "reduce-scatter":
            moved = rb * (g - 1)        # operand = result * g
        elif kind == "all-reduce":
            moved = 2 * rb * frac
        elif kind == "all-to-all":
            moved = rb * frac
        else:                           # collective-permute
            moved = rb
        ops.append(CollectiveOp(kind, moved, rb, g))
    return ops


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device interconnect bytes by collective kind (+ 'total')."""
    out: dict[str, float] = defaultdict(float)
    for op in parse_collectives(hlo_text):
        out[op.kind] += op.bytes_moved
        out["total"] += op.bytes_moved
    return dict(out)


def count_ops(hlo_text: str, names=("fusion", "all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all",
                                    "collective-permute", "dot",
                                    "convolution", "custom-call")) -> dict:
    counts = {}
    for n in names:
        counts[n] = len(re.findall(rf"\s{re.escape(n)}(?:-start)?\(", hlo_text))
    return counts
