from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.roofline.analysis import RooflineTerms, derive_terms, V5E
