"""Trip-count-aware HLO cost analyzer.

XLA's ``HloCostAnalysis`` (surfaced through ``compiled.cost_analysis()``)
visits each ``while`` body exactly once, so any program built around
``lax.scan`` — all our models scan over layers, microbatches, and loss
chunks — under-reports FLOPs, HBM bytes, and collective traffic by the loop
trip counts.  This analyzer parses the post-optimization HLO text and walks
the computation graph *multiplying loop bodies by their trip counts*:

* trip count: jax scans lower to ``while`` ops whose condition is
  ``compare(get-tuple-element(iter), constant(N)), direction=LT`` with the
  counter starting at 0 — N is the trip count.  Unrecognized conditions
  conservatively count the body once.
* FLOPs: ``dot`` ops contribute 2 x prod(result dims) x prod(contracting
  dims) (batch dims are already part of the result).  Elementwise ops are
  counted at 1 flop per result element.
* HBM bytes: for ``fusion`` ops, operands + result only (inner instructions
  stay in registers/VMEM — this is the fused kernel's true traffic).  For
  top-level non-fused ops, operands + result.
* Collectives: bytes per device using ring accounting (see hlo.py),
  multiplied by enclosing trip counts.

The result is the honest per-device (FLOPs, bytes, collective bytes) that
§Roofline needs.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_CFG = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES or dt == "token":
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str                       # args + attributes


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    types: dict[str, str] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0         # per-device interconnect traffic
    coll_by_kind: dict = field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.coll_bytes += other.coll_bytes * times
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * times


_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}
_FREE = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "copy-done")


class HloCostModel:
    def __init__(self, hlo_text: str, debug: bool = False):
        self.comps = self._parse(hlo_text)
        self.entry = self._find_entry(hlo_text)
        self._memo: dict[str, Cost] = {}
        self.debug = debug
        self.charges: dict[str, float] = {}     # instr label -> bytes

    def _charge(self, comp_name: str, ins: "Instr", b: float, mult: float):
        if self.debug and b * mult > 0:
            key = f"{ins.op}:{comp_name[:24]}:{ins.name[:40]}"
            self.charges[key] = self.charges.get(key, 0.0) + b * mult

    # -- parsing ---------------------------------------------------------------

    def _parse(self, text: str) -> dict[str, Computation]:
        comps: dict[str, Computation] = {}
        cur: Optional[Computation] = None
        for line in text.splitlines():
            if not line.startswith(" ") and "->" in line and "{" in line:
                m = _COMP_HDR.match(line.strip())
                if m:
                    cur = Computation(m.group(1))
                    comps[cur.name] = cur
                    continue
            if cur is None:
                continue
            m = _INSTR.match(line)
            if m:
                name, type_str, op, rest = m.groups()
                cur.instrs.append(Instr(name, type_str.strip(), op, rest))
                cur.types[name] = type_str.strip()
        return comps

    def _find_entry(self, text: str) -> str:
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line.strip()[len("ENTRY"):].strip() if
                                    False else line.strip())
                if m:
                    return m.group(1)
                m2 = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
                if m2:
                    return m2.group(1)
        # fallback: computation named 'main*'
        for name in self.comps:
            if name.startswith("main"):
                return name
        return next(iter(self.comps))

    # -- trip counts -------------------------------------------------------------

    def _trip_count(self, cond_name: str) -> float:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0
        bound = None
        has_lt = False
        for ins in comp.instrs:
            if ins.op == "constant" and ins.type_str.rstrip(
                    "{}0,") .endswith("[]"):
                mm = re.match(r"(\d+)\)", ins.rest)
                if mm:
                    bound = int(mm.group(1))
            if ins.op == "compare" and "direction=LT" in ins.rest:
                has_lt = True
        return float(bound) if (bound is not None and has_lt) else 1.0

    # -- per-instruction costs -------------------------------------------------------

    def _args(self, rest: str) -> list[str]:
        """Operand names from the call args (up to the closing paren).

        Commas inside shape brackets/layouts (``f32[64,64]{1,0}``) are part
        of one operand, not separators — splitting on them detaches the
        operand *name* from its position, which broke positional lookups
        (dot lhs type -> contracting dims, fusion param -> caller operand).
        """
        depth, i, out, cur = 1, 0, [], []
        nest = 0                        # []/{} nesting inside one operand
        while i < len(rest) and depth > 0:
            ch = rest[i]
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            elif ch in "[{":
                nest += 1
            elif ch in "]}":
                nest -= 1
            elif ch == "," and depth == 1 and nest == 0:
                out.append("".join(cur).strip())
                cur = []
                i += 1
                continue
            cur.append(ch)
            i += 1
        if cur:
            out.append("".join(cur).strip())
        names = []
        for a in out:
            a = a.strip()
            if a.startswith("%"):
                a = a[1:]
            names.append(a.split(" ")[-1].lstrip("%"))
        return names

    def _operand_bytes(self, comp: Computation, ins: Instr) -> float:
        total = 0.0
        for a in self._args(ins.rest):
            t = comp.types.get(a)
            if t:
                total += _type_bytes(t)
        return total

    def _fusion_bytes(self, comp: Computation, ins: Instr,
                      inner_name: str) -> float:
        """HBM traffic of a fused kernel: slice-aware reads + in-place
        writes.

        A fusion operand that is only consumed by (dynamic-)slice/gather ops
        inside the fused computation is read at the *slice* size, not the
        full buffer (scans fuse ``dynamic-slice(stacked_params, i)`` into
        consumers — charging the full stacked tensor per trip would
        over-count by the layer count).  A fusion whose root is
        dynamic-update-slice writes only the updated window (in-place
        aliasing), not the whole carried buffer.
        """
        inner = self.comps.get(inner_name)
        if inner is None:
            return self._operand_bytes(comp, ins) + _type_bytes(ins.type_str)
        args = self._args(ins.rest)
        params: list[tuple[str, int]] = []
        for iins in inner.instrs:
            if iins.op == "parameter":
                mm = re.match(r"(\d+)\)", iins.rest)
                if mm:
                    params.append((iins.name, int(mm.group(1))))
        pnames = {n for n, _ in params}
        # resolve free views (bitcast/reshape chains) back to parameters
        viewof: dict[str, str] = {}

        def _base(name: str) -> str:
            while name in viewof:
                name = viewof[name]
            return name

        sliced: dict[str, float] = {}
        nonslice: set[str] = set()
        aliased: set[str] = set()
        for iins in inner.instrs:
            if iins.op == "parameter":
                continue
            iargs = self._args(iins.rest)
            # convert counts as a view INSIDE a fusion: fused dtype changes
            # never touch HBM (XLA:CPU wraps bf16 loop buffers in converts
            # that a TPU compile does not emit — charging them would bill
            # phantom traffic against the TPU roofline)
            if iins.op in ("bitcast", "reshape", "convert") and iargs:
                viewof[iins.name] = iargs[0]
                continue
            for j, a in enumerate(iargs):
                a = _base(a)
                if a not in pnames:
                    continue
                if iins.op in ("dynamic-slice", "slice", "gather"):
                    sliced[a] = sliced.get(a, 0.0) + _type_bytes(iins.type_str)
                elif iins.op == "dynamic-update-slice" and j == 0:
                    aliased.add(a)       # in-place destination: no read
                else:
                    nonslice.add(a)
        read = 0.0
        for pname, idx in params:
            full = _type_bytes(inner.types.get(pname, ""))
            if idx < len(args):
                t = comp.types.get(args[idx])
                if t:
                    full = _type_bytes(t)
            if pname in nonslice:
                read += full
            elif pname in sliced:
                read += min(full, sliced[pname])
            elif pname in aliased:
                pass                     # write-only destination
            else:
                read += full
        # in-place write reduction: a dus producing (a view of) the fusion
        # result writes only its update window (element-count match — dtype
        # converts around the dus change bytes but not logical identity)
        write = _type_bytes(ins.type_str)
        res_elems = _type_elems(ins.type_str)
        for iins in inner.instrs:
            if iins.op != "dynamic-update-slice":
                continue
            if abs(_type_elems(iins.type_str) - res_elems) <= \
                    0.01 * max(res_elems, 1):
                upd = self._args(iins.rest)
                if len(upd) >= 2:
                    ub = _type_bytes(inner.types.get(_base(upd[1]), ""))
                    if ub:
                        write = 2 * ub      # read window + write window
                break
        return read + write

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        result_elems = _type_elems(ins.type_str)
        args = self._args(ins.rest)
        lhs_t = comp.types.get(args[0]) if args else None
        m = _LHS_CONTRACT.search(ins.rest)
        contract = 1
        if lhs_t and m and m.group(1):
            dims = _dims_of(lhs_t)
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    contract *= dims[ci]
        return 2.0 * result_elems * contract

    def _collective_cost(self, ins: Instr) -> tuple[str, float]:
        kind = ins.op.replace("-start", "")
        rb = _type_bytes(ins.type_str)
        m = _GROUPS_IOTA.search(ins.rest)
        if m:
            g = int(m.group(2))
        else:
            m = _GROUPS.search(ins.rest)
            g = (len([x for x in m.group(1).split(",") if x.strip()])
                 if m else 2)
        frac = (g - 1) / g if g > 1 else 0.0
        if kind == "all-gather":
            moved = rb * frac
        elif kind == "reduce-scatter":
            moved = rb * (g - 1)
        elif kind == "all-reduce":
            moved = 2 * rb * frac
        elif kind == "all-to-all":
            moved = rb * frac
        else:
            moved = rb
        return kind, moved

    # -- computation walk ----------------------------------------------------------------

    def _local_cost(self, comp: Computation, ins: Instr,
                    top_level: bool) -> Optional[Cost]:
        """Cost of one non-control-flow instruction (None = control flow,
        handled by the walker)."""
        op = ins.op
        base_op = op.replace("-start", "")
        c = Cost()
        if base_op in _COLLECTIVES:
            kind, moved = self._collective_cost(ins)
            c.coll_bytes += moved
            c.coll_by_kind[kind] = moved
            c.bytes += _type_bytes(ins.type_str)
            return c
        if op in ("while", "call", "conditional"):
            return None
        if op == "fusion":
            m = _CALLS.search(ins.rest)
            if m:
                inner = self.cost_of(m.group(1), False)
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
                c.bytes += self._fusion_bytes(comp, ins, m.group(1))
            else:
                c.bytes += (self._operand_bytes(comp, ins)
                            + _type_bytes(ins.type_str))
            return c
        if op in ("dynamic-slice", "slice", "gather"):
            c.bytes += 2 * _type_bytes(ins.type_str)
            return c
        if op in ("dynamic-update-slice", "scatter"):
            upd = self._args(ins.rest)
            ub = (_type_bytes(comp.types.get(upd[1], ""))
                  if len(upd) >= 2 else 0.0)
            c.bytes += 2 * (ub or _type_bytes(ins.type_str))
            return c
        if op == "dot":
            c.flops += self._dot_flops(comp, ins)
            if top_level:
                c.bytes += (self._operand_bytes(comp, ins)
                            + _type_bytes(ins.type_str))
            return c
        if op in ("sort", "rng", "reduce-window", "convolution"):
            c.flops += _type_elems(ins.type_str) * 4
            if top_level:
                c.bytes += (self._operand_bytes(comp, ins)
                            + _type_bytes(ins.type_str))
            return c
        if op not in _FREE:
            c.flops += _type_elems(ins.type_str)
            if top_level:
                c.bytes += (self._operand_bytes(comp, ins)
                            + _type_bytes(ins.type_str))
        return c

    def _trips_of(self, ins: Instr) -> float:
        m = _TRIP_CFG.search(ins.rest)
        if m:
            return float(m.group(1))            # XLA's own loop analysis
        cond = _COND.search(ins.rest)
        return self._trip_count(cond.group(1)) if cond else 1.0

    def cost_of(self, comp_name: str, top_level: bool = True) -> Cost:
        key = (comp_name, top_level)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        total = Cost()
        if comp is None:
            return total
        self._memo[key] = total      # guards recursion
        for ins in comp.instrs:
            local = self._local_cost(comp, ins, top_level)
            if local is not None:
                total.add(local)
                continue
            if ins.op == "while":
                body = _BODY.search(ins.rest)
                if body:
                    total.add(self.cost_of(body.group(1), True),
                              self._trips_of(ins))
            else:                    # call / conditional
                for callee in _CALLS.findall(ins.rest):
                    total.add(self.cost_of(callee, True), 1.0)
        return total

    def total(self) -> Cost:
        return self.cost_of(self.entry, True)

    def debug_walk(self, comp_name: Optional[str] = None, mult: float = 1.0):
        """Record per-instruction byte charges (trip-aware) in .charges."""
        self.debug = True
        comp_name = comp_name or self.entry
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            local = self._local_cost(comp, ins, True)
            if local is not None:
                self._charge(comp_name, ins, local.bytes, mult)
                continue
            if ins.op == "while":
                body = _BODY.search(ins.rest)
                if body:
                    self.debug_walk(body.group(1), mult * self._trips_of(ins))
            else:
                for callee in _CALLS.findall(ins.rest):
                    self.debug_walk(callee, mult)

    def top_charges(self, n: int = 15) -> list[tuple[str, float]]:
        if not self.charges:
            self.debug_walk()
        return sorted(self.charges.items(), key=lambda kv: -kv[1])[:n]


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()


def xla_cost_dict(raw) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns one properties dict; jax 0.4.3x returns a *list* of
    per-program dicts (usually length 1).  Merge by summing shared keys so
    callers can always ``.get("flops")``.
    """
    if isinstance(raw, dict):
        return raw
    if not raw:
        return {}
    merged: dict = {}
    for d in raw:
        for k, v in d.items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0.0) + v
            else:
                merged.setdefault(k, v)
    return merged
