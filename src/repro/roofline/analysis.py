"""Three-term roofline derivation from the compiled dry-run artifact.

Per (arch × shape × mesh):

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device on
a partitioned module — we record both raw and fleet-total), collective bytes
from the HLO parser.  Hardware constants: TPU v5e.

Also reported: MODEL_FLOPS = 6·N·D (dense train; 2·N·D inference-forward,
per-token for decode) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs,
which catches remat/redundancy waste.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float         # per chip
    hbm_bw: float             # per chip
    link_bw: float            # per chip per link


V5E = HW("tpu-v5e", 197e12, 819e9, 50e9)


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # fleet totals
    hlo_flops: float
    hlo_bytes: float
    collective_bytes_per_chip: float
    model_flops: float
    # seconds
    t_compute: float = field(init=False)
    t_memory: float = field(init=False)
    t_collective: float = field(init=False)
    hw: HW = V5E

    def __post_init__(self):
        self.t_compute = self.hlo_flops / (self.chips * self.hw.peak_flops)
        self.t_memory = self.hlo_bytes / (self.chips * self.hw.hbm_bw)
        self.t_collective = self.collective_bytes_per_chip / self.hw.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-at-peak time / bound time: how close the compiled
        program is to the ideal all-compute roofline."""
        ideal = self.model_flops / (self.chips * self.hw.peak_flops)
        return ideal / self.bound_time if self.bound_time else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6·N·D train / 2·N·D prefill / 2·N_active per decoded token."""
    n = cfg.active_param_count()
    seq = cfg.effective_seq(shape)
    tokens = shape.global_batch * seq
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n * shape.global_batch


def derive_terms(cfg: ArchConfig, shape: ShapeConfig, mesh_name: str,
                 chips: int, hlo_flops: float, hlo_bytes: float,
                 collective_bytes_per_chip: float) -> RooflineTerms:
    return RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        collective_bytes_per_chip=collective_bytes_per_chip,
        model_flops=model_flops(cfg, shape))
