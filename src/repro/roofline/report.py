"""Roofline report generator: reads reports/dryrun/ JSONs and emits the
§Dry-run and §Roofline tables for EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.roofline.report [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def load_cells(mesh: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(REPORT_DIR, mesh, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(mesh: str, markdown: bool = True) -> str:
    cells = load_cells(mesh)
    lines = []
    hdr = ("| arch | shape | dom | t_comp | t_mem | t_coll | useful | "
           "frac | HBM/dev | status |")
    sep = "|" + "---|" * 10
    lines.append(hdr)
    lines.append(sep)
    for c in cells:
        if c["status"] == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | - | - | - | - | "
                         f"- | - | - | skip: {c['reason'][:40]} |")
            continue
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | - | - | - | - | "
                         f"- | - | - | ERROR |")
            continue
        r = c["roofline"]
        mem = c["memory"].get("temp_bytes")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['dominant'][:4]} | "
            f"{fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} | "
            f"{fmt_s(r['t_collective_s'])} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {fmt_b(mem)} | ok |")
    return "\n".join(lines)


def summary(mesh: str) -> dict:
    cells = [c for c in load_cells(mesh) if c["status"] == "ok"]
    doms = {}
    for c in cells:
        doms[c["roofline"]["dominant"]] = doms.get(
            c["roofline"]["dominant"], 0) + 1
    worst = sorted(cells, key=lambda c: c["roofline"]["roofline_fraction"])
    most_coll = sorted(cells, key=lambda c: -c["roofline"]["t_collective_s"])
    return {
        "n_ok": len(cells),
        "dominant_counts": doms,
        "worst_fraction": [(c["arch"], c["shape"],
                            round(c["roofline"]["roofline_fraction"], 4))
                           for c in worst[:5]],
        "most_collective_bound": [(c["arch"], c["shape"],
                                   round(c["roofline"]["t_collective_s"], 3))
                                  for c in most_coll[:5]],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    print(f"## Roofline — {args.mesh}\n")
    print(roofline_table(args.mesh))
    print("\n## Summary\n")
    print(json.dumps(summary(args.mesh), indent=1))


if __name__ == "__main__":
    main()
