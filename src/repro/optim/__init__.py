from repro.optim.optimizers import (OptState, adamw_init, adamw_update,
                                    make_optimizer)
from repro.optim.schedules import cosine_schedule, linear_warmup
