"""Optimizers: AdamW with configurable moment dtype (fp32 / bf16 / int8).

The int8 mode stores both Adam moments block-quantized (per-256-block absmax
scales kept in fp32), cutting optimizer HBM from 8 to ~2 bytes/param — the
difference that lets nemotron-4-340b train on a 256-chip v5e pod
(DESIGN.md §6).  Moment trees inherit the parameter sharding, so quantized
blocks never cross shard boundaries in practice (block size 256 divides all
sharded dim products in the assigned configs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any
QBLOCK = 128     # one v5e lane; every sharded last-dim shard divides it


@jax.tree_util.register_pytree_node_class
class QTensor:
    """Block-quantized int8 tensor + per-block fp32 scales.

    Layout is **sharding-preserving**: quantization blocks run along the
    last dimension only, so ``q`` has exactly the parameter's shape (last
    dim padded to a QBLOCK multiple) and inherits the parameter's
    PartitionSpec unchanged; ``scale`` drops the last dim to n_blocks.
    A global flatten (the naive layout) destroys GSPMD sharding
    propagation and costs a full parameter gather per optimizer step —
    the dominant collective in the 340B-config dry-runs before this fix
    (EXPERIMENTS.md §Perf).  ``shape`` is static pytree aux data."""

    def __init__(self, q: jax.Array, scale: jax.Array, shape: tuple):
        self.q = q            # int8 [..., last_padded]
        self.scale = scale    # f32  [..., n_blocks]
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)


def quantize(x: jax.Array) -> QTensor:
    shape = tuple(x.shape) if x.ndim else (1,)
    x2 = x.reshape(shape).astype(jnp.float32)
    last = shape[-1]
    pad = (-last) % QBLOCK
    if pad:
        widths = [(0, 0)] * (len(shape) - 1) + [(0, pad)]
        x2 = jnp.pad(x2, widths)
    blocks = x2.reshape(shape[:-1] + ((last + pad) // QBLOCK, QBLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    q = q.reshape(shape[:-1] + (last + pad,)).astype(jnp.int8)
    return QTensor(q, scale, tuple(x.shape))


def dequantize(t: QTensor) -> jax.Array:
    shape = t.shape if t.shape else (1,)
    last_p = t.q.shape[-1]
    blocks = t.q.reshape(t.q.shape[:-1] + (last_p // QBLOCK, QBLOCK))
    out = blocks.astype(jnp.float32) * t.scale[..., None]
    out = out.reshape(t.q.shape[:-1] + (last_p,))[..., :shape[-1]]
    return out.reshape(t.shape)


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"     # float32 | bfloat16 | int8


def _encode_moment(x, dtype: str, positive: bool = False):
    if dtype == "int8":
        # second moment (positive, huge dynamic range): quantize in sqrt
        # domain so relative error stays bounded and small values survive
        return quantize(jnp.sqrt(x) if positive else x)
    if dtype == "bfloat16":
        return x.astype(jnp.bfloat16)
    return x.astype(jnp.float32)


def _decode_moment(x, dtype: str, positive: bool = False):
    if dtype == "int8":
        d = dequantize(x)
        return jnp.square(d) if positive else d
    return x.astype(jnp.float32)


def adamw_init(params: PyTree, cfg: AdamWConfig) -> OptState:
    zeros = jax.tree.map(
        lambda p: _encode_moment(jnp.zeros(p.shape, jnp.float32),
                                 cfg.moment_dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(lambda z: z, zeros))


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params: PyTree, grads: PyTree, state: OptState,
                 cfg: AdamWConfig, lr: Optional[jax.Array] = None
                 ) -> tuple[PyTree, OptState, dict]:
    """One AdamW step.  Works leaf-wise; moments round-trip through the
    configured encoding."""
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = _decode_moment(mu, cfg.moment_dtype)
        nu = _decode_moment(nu, cfg.moment_dtype, positive=True)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        upd = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if p.ndim >= 2:                       # decay matrices only
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return (new_p, _encode_moment(mu, cfg.moment_dtype),
                _encode_moment(nu, cfg.moment_dtype, positive=True))

    is_q = lambda x: isinstance(x, QTensor)
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    mu_leaves = jax.tree.flatten(state.mu, is_leaf=is_q)[0]
    nu_leaves = jax.tree.flatten(state.nu, is_leaf=is_q)[0]
    trip = [leaf(p, g, m, n) for p, g, m, n
            in zip(p_leaves, g_leaves, mu_leaves, nu_leaves)]
    new_p = treedef.unflatten([t[0] for t in trip])
    new_mu = treedef.unflatten([t[1] for t in trip])
    new_nu = treedef.unflatten([t[2] for t in trip])
    return new_p, OptState(step, new_mu, new_nu), {"grad_norm": gnorm}


def make_optimizer(moment_dtype: str = "float32", **kw):
    cfg = AdamWConfig(moment_dtype=moment_dtype, **kw)

    def init(params):
        return adamw_init(params, cfg)

    def update(params, grads, state, lr=None):
        return adamw_update(params, grads, state, cfg, lr)

    return cfg, init, update
