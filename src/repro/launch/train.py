"""Production training driver.

Wires every substrate together: synthetic data pipeline -> sharded
train_step (pjit) -> checkpointing (async, keep-last-k) -> fault-tolerance
coordinator (heartbeats, straggler log, elastic restart hook).

On this CPU container it runs reduced configs end-to-end (the quickstart
and examples call into it); on a pod the same driver runs the full configs —
the only difference is the mesh passed in.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.sharded import CheckpointManager, latest_step
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.coordinator import Coordinator, CoordinatorConfig
from repro.launch import shardings as shlib
from repro.models.sharding import use_mesh
from repro.train.step import TrainConfig, TrainState, make_train_step


def train(cfg, *, steps: int = 50, batch: int = 8, seq: int = 128,
          tc: Optional[TrainConfig] = None, mesh=None, seed: int = 0,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          log_every: int = 10, coordinator: Optional[Coordinator] = None,
          frontend_batch=None, verbose: bool = True):
    """Train ``cfg`` on the synthetic corpus; returns (state, loss_history)."""
    tc = tc or TrainConfig(total_steps=steps, warmup_steps=max(1, steps // 10))
    init_state, train_step = make_train_step(cfg, tc)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    state = None
    start_step = 0
    if mgr and latest_step(ckpt_dir) is not None:
        template = jax.eval_shape(init_state, jax.random.PRNGKey(seed))
        state = mgr.restore(template)
        start_step = int(np.asarray(state.opt.step))
        if verbose:
            print(f"[train] restored checkpoint at step {start_step}")
    if state is None:
        state = init_state(jax.random.PRNGKey(seed))

    if mesh is not None:
        state_sh = shlib.train_state_shardings(
            jax.eval_shape(init_state, jax.random.PRNGKey(seed)), cfg, mesh)
        state = jax.device_put(state, state_sh)
        jstep = jax.jit(train_step, in_shardings=(state_sh, None),
                        out_shardings=(state_sh, None))
    else:
        jstep = jax.jit(train_step)

    if cfg.frontend == "none":
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                      global_batch=batch, seed=seed)).batches()
    else:
        assert frontend_batch is not None, \
            "stub-frontend archs need a frontend_batch factory"
        data = iter(frontend_batch, None)

    coord = coordinator
    losses = []
    t_start = time.time()
    ctx = use_mesh(mesh) if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, steps):
            t0 = time.time()
            batch_np = next(data)
            state, metrics = jstep(state, {k: jax.numpy.asarray(v)
                                           for k, v in batch_np.items()})
            loss = float(metrics["loss"])
            losses.append(loss)
            if coord is not None:
                coord.report_step(0, time.time() - t0)
                coord.check()
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save(state, step + 1)
            if verbose and (step + 1) % log_every == 0:
                dt = (time.time() - t_start) / (step + 1 - start_step)
                print(f"[train] step {step+1:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms/step)")
    if mgr:
        mgr.save(state, steps)
        mgr.wait_all()
    return state, losses


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-sized) config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tc = TrainConfig(remat=args.remat, n_micro=args.n_micro,
                     grad_compress=args.grad_compress,
                     moment_dtype=cfg.moment_dtype,
                     total_steps=args.steps,
                     warmup_steps=max(1, args.steps // 10))
    coord = Coordinator(1, CoordinatorConfig())
    _, losses = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                      tc=tc, ckpt_dir=args.ckpt_dir, seed=args.seed,
                      coordinator=coord)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
