import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import: JAX locks the device
# count at first initialization.  Dry-runs keep bf16 dots un-upcast (they
# never execute, so the CPU DotThunk limitation is irrelevant).
os.environ.setdefault("REPRO_SAFE_DOT", "0")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the full production step function — train_step
(train shapes), serve_prefill (prefill shapes) or serve_decode (decode
shapes) — resolves in/out shardings on the production mesh, lowers with
ShapeDtypeStruct inputs (no allocation), compiles, and records:

  * memory_analysis()  — proves the per-device footprint fits HBM,
  * cost_analysis()    — HLO FLOPs / bytes for §Roofline,
  * parsed collective bytes (roofline/hlo.py),
  * lowering/compile wall time and HLO op counts.

Results are cached as JSON under reports/dryrun/; EXPERIMENTS.md §Dry-run
and §Roofline are generated from these files.

Usage:
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--quick]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.configs.registry import ALL_SHAPES, ARCH_IDS, get_config, get_shape
from repro.data.pipeline import make_batch_specs
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.registry import serve_decode, serve_prefill
from repro.models.sharding import use_mesh
from repro.roofline.analysis import derive_terms, model_flops
from repro.roofline.hlo import collective_bytes, count_ops
from repro.train.step import TrainConfig, make_train_step

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def _n_micro(cfg: ArchConfig, shape: ShapeConfig, mesh) -> int:
    """Microbatch so ~2 batch rows are live per device per microstep —
    bounds activation memory for every arch at every mesh size.

    Perf note: FSDP weight gathers and wgrad reductions repeat per
    microbatch, so collective volume scales with n_micro — the hillclimb
    halves it for the collective-bound 300B configs (4 rows live instead
    of 2; REPRO_NMICRO overrides for experiments)."""
    if os.environ.get("REPRO_NMICRO"):
        return int(os.environ["REPRO_NMICRO"])
    from repro.models.sharding import data_axes
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    rows_per_dev = max(1, shape.global_batch // dp)
    divisor = 4 if cfg.param_count() > 100e9 else 2
    return int(min(16, max(1, rows_per_dev // divisor)))


def build_train(cfg: ArchConfig, shape: ShapeConfig, mesh):
    tc = TrainConfig(remat="dots", n_micro=_n_micro(cfg, shape, mesh),
                     moment_dtype=cfg.moment_dtype,
                     loss_chunk=512)
    init_state, train_step = make_train_step(cfg, tc)
    state_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    state_sh = sh.train_state_shardings(state_shapes, cfg, mesh)
    batch_shapes = make_batch_specs(cfg, shape)
    batch_sh = sh.batch_shardings(batch_shapes, mesh)
    metrics_sh = None
    fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                 out_shardings=(state_sh, metrics_sh))
    return fn, (state_shapes, batch_shapes)


def build_prefill(cfg: ArchConfig, shape: ShapeConfig, mesh):
    S = cfg.effective_seq(shape)
    B = shape.global_batch

    def prefill_fn(params, batch):
        return serve_prefill(params, cfg, batch, max_len=S)

    from repro.models.registry import init_model
    params_shapes = jax.eval_shape(lambda k: init_model(cfg, k),
                                   jax.random.PRNGKey(0))
    p_sh = sh.params_shardings(params_shapes, cfg, mesh)
    batch_shapes = make_batch_specs(cfg, shape)
    batch_shapes.pop("labels", None)
    batch_sh = sh.batch_shardings(batch_shapes, mesh)
    # outputs: (logits [B,V], caches)
    cache_shapes = jax.eval_shape(
        lambda: transformer.init_caches(cfg, B, S))
    out_sh = (sh.logits_sharding(mesh, cfg.vocab_size, B),
              sh.cache_shardings(cache_shapes, cfg, mesh, B))
    fn = jax.jit(prefill_fn, in_shardings=(p_sh, batch_sh),
                 out_shardings=out_sh)
    return fn, (params_shapes, batch_shapes)


def build_decode(cfg: ArchConfig, shape: ShapeConfig, mesh):
    from repro.models.registry import init_model
    S = cfg.effective_seq(shape)
    B = shape.global_batch

    def decode_fn(params, token, pos, caches):
        return serve_decode(params, cfg, token, pos, caches)

    params_shapes = jax.eval_shape(lambda k: init_model(cfg, k),
                                   jax.random.PRNGKey(0))
    p_sh = sh.params_shardings(params_shapes, cfg, mesh)
    cache_shapes = jax.eval_shape(lambda: transformer.init_caches(cfg, B, S))
    cache_sh = sh.cache_shardings(cache_shapes, cfg, mesh, B)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    tok_sh = sh.batch_shardings({"t": tok}, mesh)["t"]
    out_sh = (sh.logits_sharding(mesh, cfg.vocab_size, B), cache_sh)
    # donate the KV caches: the decode step updates one token in place —
    # without donation XLA materializes a full second cache every step
    fn = jax.jit(decode_fn,
                 in_shardings=(p_sh, tok_sh, sh.replicated(mesh), cache_sh),
                 out_shardings=out_sh, donate_argnums=(3,))
    return fn, (params_shapes, tok, pos, cache_shapes)


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             hlo_snippet: bool = False) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, reason = cfg.shape_applicable(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    with mesh, use_mesh(mesh):
        fn, arg_shapes = BUILDERS[shape.kind](cfg, shape, mesh)
        lowered = fn.lower(*arg_shapes)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    # cost_analysis() returns a list of per-program dicts on jax 0.4.3x and
    # a plain dict on older versions — normalize before .get() below.
    from repro.roofline.hlo_cost import xla_cost_dict
    cost = xla_cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    ops = count_ops(hlo)

    # Trip-count-aware analysis (XLA's cost_analysis counts while bodies
    # once, under-reporting every lax.scan — see roofline/hlo_cost.py).
    from repro.roofline.hlo_cost import analyze
    acc = analyze(hlo)
    flops_per_dev = acc.flops
    bytes_per_dev = acc.bytes
    coll = dict(acc.coll_by_kind)
    coll["total"] = acc.coll_bytes
    terms = derive_terms(cfg, shape, mesh_name, chips,
                         hlo_flops=flops_per_dev * chips,
                         hlo_bytes=bytes_per_dev * chips,
                         collective_bytes_per_chip=coll.get("total", 0.0))

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips, "kind": shape.kind,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {"flops_per_device": flops_per_dev,
                 "bytes_per_device": bytes_per_dev,
                 "xla_flops_per_device": float(cost.get("flops", 0.0)),
                 "xla_bytes_per_device": float(cost.get("bytes accessed",
                                                        0.0))},
        "collectives": coll,
        "hlo_ops": ops,
        "roofline": terms.row(),
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }
    if hlo_snippet:
        result["hlo_head"] = hlo[:4000]
    return result


def cell_path(arch: str, shape_name: str, multi_pod: bool) -> str:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(REPORT_DIR, mesh_name), exist_ok=True)
    return os.path.join(REPORT_DIR, mesh_name, f"{arch}__{shape_name}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[s.name for s in ALL_SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    cells = ([(args.arch, args.shape)] if not args.all else
             [(a, s.name) for a in ARCH_IDS for s in ALL_SHAPES])
    failures = 0
    for arch, shape_name in cells:
        path = cell_path(arch, shape_name, args.multi_pod)
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                print(f"[cached] {arch} x {shape_name}")
                continue
        print(f"[dryrun] {arch} x {shape_name} "
              f"({'multi' if args.multi_pod else 'single'}-pod) ...",
              flush=True)
        try:
            res = run_cell(arch, shape_name, args.multi_pod)
        except Exception as e:                         # noqa: BLE001
            res = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-3000:]}
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = ""
        if status == "ok":
            r = res["roofline"]
            extra = (f" dom={r['dominant']}"
                     f" frac={r['roofline_fraction']:.3f}"
                     f" lower={res['t_lower_s']}s comp={res['t_compile_s']}s")
        elif status == "error":
            extra = " " + res["error"][:120]
        print(f"  -> {status}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
