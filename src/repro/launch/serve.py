"""Production serving driver: SlotServer under LithOS multi-tenancy.

Runs the continuous-batching engine (serve/engine.py) over a synthetic
request stream and reports latency/throughput; with ``--collocated`` it
additionally runs the LithOS simulator to show the same workload stacked
with a best-effort tenant under each scheduling system.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 32 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.serve.engine import ServeConfig, SlotServer


def serve(cfg, *, n_requests: int = 16, max_slots: int = 4,
          max_len: int = 128, max_new: int = 16, seed: int = 0,
          verbose: bool = True):
    rng = np.random.default_rng(seed)
    t0 = time.time()
    srv = SlotServer(cfg, serve_cfg=ServeConfig(
        max_slots=max_slots, max_len=max_len, max_new_tokens=max_new),
        seed=seed, clock=lambda: time.time() - t0)
    for _ in range(n_requests):
        plen = int(rng.integers(4, max_len // 2))
        srv.submit(rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=max_new)
    done = srv.run_until_drained()
    lats = srv.latencies()
    if verbose:
        toks = sum(len(r.output) for r in done)
        wall = time.time() - t0
        print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s "
              f"({toks/wall:.1f} tok/s) p50={np.percentile(lats,50)*1e3:.0f}ms "
              f"p99={np.percentile(lats,99)*1e3:.0f}ms")
    return done, lats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("SlotServer serves decoder-only configs; "
                         "whisper uses examples/whisper_decode.py")
    serve(cfg, n_requests=args.requests, max_slots=args.max_slots,
          max_len=args.max_len, max_new=args.max_new, seed=args.seed)


if __name__ == "__main__":
    main()
