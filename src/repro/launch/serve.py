"""Production serving driver: SlotServer under LithOS multi-tenancy.

Runs the continuous-batching engine (serve/engine.py) over a synthetic
request stream and reports latency/throughput; with ``--collocated`` it
additionally runs the LithOS simulator to show the same workload stacked
with a best-effort tenant under each scheduling system.

With ``--ctl-state-dir`` the driver does not serve locally at all: it is
the first client of the online control plane (:mod:`repro.ctl`), and the
invocation becomes a *job submission* — the serve deployment turns into a
tenant (SLO class + slice quota) that the daemon admits onto a device and
runs under multi-tenancy, survivable across daemon crashes.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 32 --max-new 8
    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b \
        --ctl-state-dir /tmp/ctl --rps 40 --duration 5 --quota 8 --slo 0.25
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs.registry import ARCH_IDS, get_config


def serve(cfg, *, n_requests: int = 16, max_slots: int = 4,
          max_len: int = 128, max_new: int = 16, seed: int = 0,
          verbose: bool = True):
    # deferred: the --ctl-state-dir submit path must not pay (or require)
    # the jax import just to drop a spec file in the daemon's inbox
    from repro.serve.engine import ServeConfig, SlotServer

    rng = np.random.default_rng(seed)
    t0 = time.time()
    srv = SlotServer(cfg, serve_cfg=ServeConfig(
        max_slots=max_slots, max_len=max_len, max_new_tokens=max_new),
        seed=seed, clock=lambda: time.time() - t0)
    for _ in range(n_requests):
        plen = int(rng.integers(4, max_len // 2))
        srv.submit(rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=max_new)
    done = srv.run_until_drained()
    lats = srv.latencies()
    if verbose:
        toks = sum(len(r.output) for r in done)
        wall = time.time() - t0
        print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s "
              f"({toks/wall:.1f} tok/s) p50={np.percentile(lats,50)*1e3:.0f}ms "
              f"p99={np.percentile(lats,99)*1e3:.0f}ms")
    return done, lats


def submit_to_ctl(args) -> str:
    """Express this serve deployment as a control-plane job: an open-loop
    ``serve`` tenant with the CLI's SLO class and slice quota.  Returns the
    job id; the daemon owning ``--ctl-state-dir`` admits and runs it."""
    from repro.ctl import store

    spec = {"kind": "serve", "arch": args.arch, "reduced": args.reduced,
            "name": args.name or f"serve-{args.arch}",
            "priority": args.priority, "quota_slices": args.quota,
            "rps": args.rps, "duration": args.duration,
            "slo_latency": args.slo, "batch": args.max_slots,
            "decode_tokens": args.max_new, "seed": args.seed}
    return store.request_submit(args.ctl_state_dir, spec)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ctl = ap.add_argument_group("control plane (submit instead of serving)")
    ctl.add_argument("--ctl-state-dir", default=None,
                     help="submit this deployment as a ctl job instead of "
                          "serving locally")
    ctl.add_argument("--name", default=None)
    ctl.add_argument("--priority", default="hp", choices=["hp", "be"])
    ctl.add_argument("--quota", type=int, default=0,
                     help="pinned TPC slices for the tenant")
    ctl.add_argument("--rps", type=float, default=20.0)
    ctl.add_argument("--duration", type=float, default=5.0,
                     help="serve window, simulated seconds")
    ctl.add_argument("--slo", type=float, default=0.25,
                     help="SLO latency target, seconds")
    args = ap.parse_args(argv)
    if args.ctl_state_dir is not None:
        print(submit_to_ctl(args))
        return
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("SlotServer serves decoder-only configs; "
                         "whisper uses examples/whisper_decode.py")
    serve(cfg, n_requests=args.requests, max_slots=args.max_slots,
          max_len=args.max_len, max_new=args.max_new, seed=args.seed)


if __name__ == "__main__":
    main()
