"""Production mesh factory.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any JAX
initialization, and smoke tests must keep seeing 1 device.

Meshes:
    single-pod : (16, 16)    = ("data", "model")            256 chips
    multi-pod  : (2, 16, 16) = ("pod", "data", "model")     512 chips

The ``pod`` axis composes with ``data`` for gradient reduction
(hierarchical: reduce-scatter intra-pod over ICI, all-reduce across pods
over DCN); the ``model`` axis stays inside one pod's ICI domain.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    assert len(devs) >= n, (f"need {n} devices for the production mesh; "
                            f"have {len(devs)} — is XLA_FLAGS set?")
    # dry-run process exposes 512 placeholder devices; the single-pod mesh
    # takes the first 256
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Elastic variant: any (pods, data, model) factorization of the
    available device count (used by the elastic-scaling tests)."""
    return jax.make_mesh(shape, axes)


def single_device_mesh() -> Mesh:
    return jax.make_mesh((1, 1), ("data", "model"))
