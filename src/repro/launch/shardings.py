"""Sharding resolution for whole train/serve states on a production mesh.

Builds NamedShardings for:
* parameter trees        — logical axes (models/common.py) -> mesh axes via
                           the rule-sets in models/sharding.py;
* optimizer state        — moments mirror parameter shardings; int8 QTensor
                           moments shard their flat block dim over all mesh
                           axes when divisible (else replicate — only tiny
                           leaves like norm scales hit this);
* batches                — batch dim over ("pod","data");
* KV caches/decode state — batch dim over ("pod","data"), head dims over
                           "model" when divisible (GQA kv-head counts below
                           the TP degree replicate, documented).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.common import logical_axes
from repro.models.sharding import data_axes, param_shardings
from repro.optim.optimizers import QTensor

PyTree = Any


def default_ruleset(cfg: ArchConfig) -> str:
    """fsdp_tp for the very large configs (params must shard over data too),
    tp_dp otherwise."""
    return "fsdp_tp" if cfg.param_count() > 20e9 else "tp_dp"


def use_ep(cfg: ArchConfig) -> bool:
    return cfg.moe is not None and cfg.moe.parallelism == "ep"


def _dp(mesh: Mesh):
    d = data_axes(mesh)
    return d if len(d) > 1 else (d[0] if d else None)


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _all_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def params_shardings(params_shapes: PyTree, cfg: ArchConfig, mesh: Mesh,
                     ruleset: Optional[str] = None) -> PyTree:
    rs = ruleset or default_ruleset(cfg)
    axes = logical_axes(params_shapes)
    return param_shardings(axes, mesh, rs, ep=use_ep(cfg),
                           shapes=params_shapes)


def _qtensor_sharding(qt_shapes: QTensor, p_sharding: NamedSharding,
                      mesh: Mesh) -> QTensor:
    """int8 moments are layout-compatible with their parameter: ``q`` takes
    the parameter's spec verbatim; ``scale`` drops the last-dim axis (its
    block dim rarely divides the TP degree)."""
    spec = p_sharding.spec
    ndim = len(qt_shapes.shape) or 1
    parts = list(spec) + [None] * (ndim - len(spec))
    # q: check the padded last dim still divides; else replicate that dim
    q_parts = list(parts)
    last_ax = q_parts[-1] if q_parts else None
    if last_ax is not None:
        axes = (last_ax,) if isinstance(last_ax, str) else tuple(last_ax)
        sz = math.prod(mesh.shape[a] for a in axes)
        if qt_shapes.q.shape[-1] % sz != 0:
            q_parts[-1] = None
    s_parts = list(q_parts[:-1]) + [None]
    if qt_shapes.scale.ndim > len(s_parts):
        s_parts += [None] * (qt_shapes.scale.ndim - len(s_parts))
    s_parts = s_parts[:qt_shapes.scale.ndim]
    return QTensor(NamedSharding(mesh, P(*q_parts)),
                   NamedSharding(mesh, P(*s_parts)),
                   qt_shapes.shape)


def moments_shardings(mu_shapes: PyTree, p_shardings: PyTree,
                      mesh: Mesh) -> PyTree:
    """mu/nu mirror params; QTensor leaves use the flat-block rule."""
    is_q = lambda x: isinstance(x, QTensor)
    mu_leaves, treedef = jax.tree_util.tree_flatten(mu_shapes, is_leaf=is_q)
    p_leaves = jax.tree_util.tree_flatten(p_shardings,
                                          is_leaf=lambda x: isinstance(
                                              x, NamedSharding))[0]
    out = []
    for m, p in zip(mu_leaves, p_leaves):
        out.append(_qtensor_sharding(m, p, mesh) if is_q(m) else p)
    return jax.tree_util.tree_unflatten(treedef, out)


def train_state_shardings(state_shapes, cfg: ArchConfig, mesh: Mesh,
                          ruleset: Optional[str] = None):
    """Shardings matching train.step.TrainState(params, opt, err_fb)."""
    from repro.train.step import TrainState
    from repro.optim.optimizers import OptState
    p_sh = params_shardings(state_shapes.params, cfg, mesh, ruleset)
    step_sh = NamedSharding(mesh, P())
    mu_sh = moments_shardings(state_shapes.opt.mu, p_sh, mesh)
    nu_sh = moments_shardings(state_shapes.opt.nu, p_sh, mesh)
    err_sh = (jax.tree.map(lambda s: s, p_sh)
              if state_shapes.err_fb is not None else None)
    return TrainState(p_sh, OptState(step_sh, mu_sh, nu_sh), err_sh)


def batch_shardings(batch_shapes: dict, mesh: Mesh) -> dict:
    dp_total = math.prod([mesh.shape[a] for a in data_axes(mesh)]) or 1
    dp = _dp(mesh)
    out = {}
    for k, v in batch_shapes.items():
        spec = [None] * v.ndim
        if v.ndim >= 1 and v.shape[0] % dp_total == 0:
            spec[0] = dp             # batch too small to shard: replicate
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(cache_shapes: PyTree, cfg: ArchConfig, mesh: Mesh,
                    batch: int) -> PyTree:
    """Generic decode-state sharding: batch dim over data axes; head dims
    over model when divisible; otherwise the **sequence** dim of KV caches
    shards over model (GQA head counts below the TP degree replicate heads
    but must not replicate the cache — attention against a seq-sharded
    cache is a local partial-softmax plus a small cross-shard combine,
    which GSPMD emits automatically).  See EXPERIMENTS.md §Perf."""
    dp = _dp(mesh)
    tp = _model_size(mesh)
    dp_total = math.prod([mesh.shape[a] for a in data_axes(mesh)]) or 1
    headish = {cfg.n_kv_heads, cfg.n_heads}

    def leaf(x):
        spec: list = [None] * x.ndim
        # batch dim: first dim equal to the global batch (never the leading
        # layer-stack dim of scanned caches, which can collide with head
        # counts — hence the positional rules below)
        b_i = next((i for i, d in enumerate(x.shape)
                    if d == batch and d % dp_total == 0), None)
        if b_i is not None:
            spec[b_i] = dp
        if b_i is not None and x.ndim - b_i == 4:
            # KV-cache layout [.., B, S, H, Dh]: prefer heads over model;
            # GQA head counts below the TP degree shard the sequence
            s_i, h_i = b_i + 1, b_i + 2
            if x.shape[h_i] % tp == 0:
                spec[h_i] = "model"
            elif x.shape[s_i] % tp == 0:
                spec[s_i] = "model"
        else:
            # recurrent states etc.: any later head/width dim that divides
            for i in range((b_i + 1) if b_i is not None else 1, x.ndim):
                if x.shape[i] in headish and x.shape[i] % tp == 0:
                    spec[i] = "model"
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache_shapes)


def logits_sharding(mesh: Mesh, vocab: int, batch: int = 0) -> NamedSharding:
    tp = _model_size(mesh)
    dp_total = math.prod([mesh.shape[a] for a in data_axes(mesh)]) or 1
    dp = _dp(mesh) if batch % dp_total == 0 else None
    return NamedSharding(mesh, P(dp, "model" if vocab % tp == 0 else None))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
