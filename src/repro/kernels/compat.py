"""JAX version compatibility for the Pallas kernels.

``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams`` in newer
JAX; support both so the kernels run on the pinned toolchain and on
freshly-installed CI environments alike.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
