"""Atomizable tiled matmul — the TPU-native form of LithOS kernel atomization.

The paper's Kernel Atomizer (§4.4) splits a CUDA kernel's grid of thread
blocks into contiguous block-index ranges ("atoms") via a Prelude kernel that
early-exits blocks outside ``[start, start+len)``.  On TPU the grid is
software-controlled, so an atom is expressed *exactly* — an offset BlockSpec
index map over a sub-grid — with zero early-exit waste (beyond-paper win, see
DESIGN.md §2).

    C[M,N] = A[M,K] @ B[K,N]

is tiled (bm, bn, bk); the 2-D output tile space (nm x nn) is flattened
row-major into ``T = nm*nn`` schedulable tiles.  One atom executes tiles
``[start, start+num_tiles)`` over the full K reduction:

    grid = (num_tiles, nk)       # ("arbitrary", "arbitrary") semantics
    A tile  (t, k) -> (m(start+t), k)
    B tile  (t, k) -> (k, n(start+t))
    C tile  (t, k) -> (m(start+t), n(start+t))

The running output C is passed in and aliased to the output buffer
(``input_output_aliases``), so tiles outside the atom pass through untouched
and atoms compose: running every atom once, in any order, over disjoint
ranges covering [0, T) yields exactly ``A @ B`` (property-tested).

f32 accumulation lives in a VMEM scratch tile; the cast to the output dtype
happens once per tile at the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _matmul_atom_kernel(a_ref, b_ref, c_in_ref, c_ref, acc_ref, *, nk: int):
    """One (tile, k) grid step: accumulate a_tile @ b_tile into acc scratch."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


def matmul_atom(a: jax.Array, b: jax.Array, c: jax.Array, *, start: int,
                num_tiles: int, block_m: int = 256, block_n: int = 256,
                block_k: int = 256, interpret: bool = False) -> jax.Array:
    """Execute one atom: output tiles [start, start+num_tiles) of ``a @ b``.

    ``c`` is the running output (aliased to the result); tiles outside the
    atom are preserved.  All of M, N, K must divide by the block sizes
    (``ops.atom_matmul`` pads).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and c.shape == (M, N)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        (M, N, K), (block_m, block_n, block_k))
    nm, nn, nk = M // block_m, N // block_n, K // block_k
    total = nm * nn
    assert 0 <= start and start + num_tiles <= total, (start, num_tiles, total)

    def mi(t):
        return (start + t) // nn

    def ni(t):
        return (start + t) % nn

    kernel = functools.partial(_matmul_atom_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(num_tiles, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda t, k: (mi(t), k)),
            pl.BlockSpec((block_k, block_n), lambda t, k: (k, ni(t))),
            pl.BlockSpec((block_m, block_n), lambda t, k: (mi(t), ni(t))),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda t, k: (mi(t), ni(t))),
        out_shape=jax.ShapeDtypeStruct((M, N), c.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        input_output_aliases={2: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b, c)


def tile_count(M: int, N: int, block_m: int = 256, block_n: int = 256) -> int:
    """Schedulable tiles for an (M, N) output — the atomizer's grid size."""
    return -(-M // block_m) * -(-N // block_n)
