"""jit'd wrappers: full matmul as a schedule of atoms.

``atom_matmul`` is the public op.  It pads operands to tile multiples, splits
the output tile space into ``n_atoms`` contiguous ranges (the schedule a
LithOS dispatcher would emit), executes them in the given order, and unpads.
With ``n_atoms=1`` it is a plain tiled Pallas matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.atom_matmul.kernel import matmul_atom, tile_count


def atom_ranges(total_tiles: int, n_atoms: int) -> list[tuple[int, int]]:
    """Split [0, total) into n contiguous (start, len) ranges (len may differ
    by 1) — the atomizer's default schedule."""
    n_atoms = max(1, min(n_atoms, total_tiles))
    base, rem = divmod(total_tiles, n_atoms)
    out, start = [], 0
    for i in range(n_atoms):
        ln = base + (1 if i < rem else 0)
        out.append((start, ln))
        start += ln
    return out


def _pad2(x, m0, m1):
    p0, p1 = (-x.shape[0]) % m0, (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=(
    "n_atoms", "block_m", "block_n", "block_k", "interpret", "order"))
def atom_matmul(a: jax.Array, b: jax.Array, *, n_atoms: int = 1,
                block_m: int = 256, block_n: int = 256, block_k: int = 256,
                interpret: bool = False, order: tuple[int, ...] = ()) -> jax.Array:
    """``a @ b`` computed as ``n_atoms`` independently scheduled atoms.

    ``order`` optionally permutes atom execution (scheduling is order-free
    because atom tile ranges are disjoint — property-tested).
    """
    M, N = a.shape[0], b.shape[1]
    ap = _pad2(a, block_m, block_k)
    bp = _pad2(b, block_k, block_n)
    Mp, Np = ap.shape[0], bp.shape[1]
    total = tile_count(Mp, Np, block_m, block_n)
    ranges = atom_ranges(total, n_atoms)
    if order:
        assert sorted(order) == list(range(len(ranges))), order
        ranges = [ranges[i] for i in order]
    c = jnp.zeros((Mp, Np), a.dtype)
    for start, ln in ranges:
        c = matmul_atom(ap, bp, c, start=start, num_tiles=ln,
                        block_m=block_m, block_n=block_n, block_k=block_k,
                        interpret=interpret)
    return c[:M, :N]
