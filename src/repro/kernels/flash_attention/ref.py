"""Pure-jnp oracle for flash attention (GQA, causal, f32 math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, sm_scale=None):
    """q: [B,Sq,Hq,D]; k/v: [B,Sk,Hk,D] -> [B,Sq,Hq,D]."""
    B, Sq, Hq, D = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    scale = sm_scale if sm_scale is not None else 1.0 / jnp.sqrt(D)
    qg = q.reshape(B, Sq, Hk, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if causal:
        qpos = (Sk - Sq) + jnp.arange(Sq)[:, None]
        mask = jnp.arange(Sk)[None, :] <= qpos
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
