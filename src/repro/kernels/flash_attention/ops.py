"""jit'd wrapper: full GQA flash attention as a schedule of atoms.

Public layout matches the model stack: q [B,S,Hq,D], k/v [B,S,Hk,D].
Sequence lengths are padded to block multiples (padded KV is masked by the
causal test for pad-at-end; for non-causal, padded keys are suppressed by a
-inf additive trick on the padded rows being zero — we instead require exact
multiples and pad q only, masking output rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.atom_matmul.ops import atom_ranges
from repro.kernels.flash_attention.kernel import flash_attention_atom


@functools.partial(jax.jit, static_argnames=(
    "causal", "n_atoms", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, n_atoms: int = 1,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False):
    """[B,Sq,Hq,D] x [B,Sk,Hk,D] -> [B,Sq,Hq,D] via atomized Pallas flash."""
    B, Sq, Hq, D = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    block_q = min(block_q, max(Sq, 16))
    block_k = min(block_k, max(Sk, 16))
    sm_scale = 1.0 / (D ** 0.5)

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    # pad queries at the FRONT (so causal alignment to the end of K holds)
    # and keys at the END (masked out by the causal test for the real rows;
    # padded q rows are discarded).
    qp = jnp.pad(q, ((0, 0), (pad_q, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    if pad_k and not causal:
        raise NotImplementedError("non-causal requires Sk % block_k == 0")

    Sqp, Skp = qp.shape[1], kp.shape[1]
    # kernel-internal layout [B*H, S, D]
    qf = qp.transpose(0, 2, 1, 3).reshape(B * Hq, Sqp, D)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * Hk, Skp, D)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * Hk, Skp, D)

    total = (B * Hq) * (Sqp // block_q)
    o = jnp.zeros_like(qf)
    # Padded keys sit at the end: shifting all q positions by -pad_k makes
    # real query j (padded row pad_q + j) see exactly keys <= Sk - Sq + j and
    # never a padded key; padded q rows are fully masked and discarded.
    q_pos_offset = -pad_k
    for start, ln in atom_ranges(total, n_atoms):
        o = flash_attention_atom(
            qf, kf, vf, o, start=start, num_tiles=ln, sm_scale=sm_scale,
            causal=causal, block_q=block_q, block_k=block_k,
            q_pos_offset=q_pos_offset, interpret=interpret)
    o = o.reshape(B, Hq, Sqp, D).transpose(0, 2, 1, 3)
    return o[:, pad_q:]
