"""Atomizable GQA flash-attention Pallas kernel (TPU target).

Flash attention with online softmax; the schedulable tile space is the
flattened (batch x q_head x q_block) dimension, so — like ``atom_matmul`` —
a LithOS atom is a contiguous range ``[start, start+num_tiles)`` of that
space, expressed with offset BlockSpec index maps (no early-exit waste).

Layouts (kernel-internal):
    q  [B*Hq, Sq, D]        k/v  [B*Hk, Sk, D]        o  [B*Hq, Sq, D]

GQA is resolved in the index maps: tile t serves flat q-row ``bh``, which
reads kv-row ``(bh // Hq) * Hk + (bh % Hq) // (Hq // Hk)``.

Causal masking aligns the query block to the *end* of the key range
(``qpos = Sk - Sq + global_q_index``), covering both self-attention
(Sq == Sk) and chunked prefill (Sq < Sk).  Fully-masked KV blocks are
skipped with ``pl.when`` — on TPU the grid is sequential, so a skipped step
costs one loop iteration, not a dead thread-block launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_in_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, sm_scale: float, causal: bool, nk: int, block_q: int,
                  block_k: int, q_pos_offset: int, start: int, n_qblocks: int):
    t, ki = pl.program_id(0), pl.program_id(1)
    qi = (start + t) % n_qblocks

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = q_pos_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, 1), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)

    # visit only KV blocks with at least one unmasked element
    if causal:
        block_needed = ki * block_k <= (q_pos_offset + qi * block_q
                                        + block_q - 1)
    else:
        block_needed = ki >= 0                        # traced "always true"

    @pl.when(block_needed)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # [bq, D]
        k = k_ref[0].astype(jnp.float32)             # [bk, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        if causal:
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]      # [bq,1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_atom(q, k, v, o, *, start: int, num_tiles: int,
                         sm_scale: float, causal: bool = True,
                         block_q: int = 512, block_k: int = 512,
                         q_pos_offset: int = 0,
                         interpret: bool = False) -> jax.Array:
    """One atom of flash attention over flat tiles [start, start+num_tiles).

    q: [BHq, Sq, D]; k/v: [BHk, Sk, D]; o: running output [BHq, Sq, D]
    (aliased — tiles outside the atom pass through).
    """
    BHq, Sq, D = q.shape
    BHk, Sk, _ = k.shape
    assert BHq % BHk == 0
    G = BHq // BHk                         # q rows per kv row (within a batch)
    assert Sq % block_q == 0 and Sk % block_k == 0
    n_qblocks = Sq // block_q
    nk = Sk // block_k
    total = BHq * n_qblocks
    assert 0 <= start and start + num_tiles <= total

    def bh(t):
        return (start + t) // n_qblocks

    def qi(t):
        return (start + t) % n_qblocks

    def kvh(t):
        return bh(t) // G

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, nk=nk,
        block_q=block_q, block_k=block_k, q_pos_offset=q_pos_offset + Sk - Sq,
        start=start, n_qblocks=n_qblocks)
    return pl.pallas_call(
        kernel,
        grid=(num_tiles, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda t, ki: (bh(t), qi(t), 0)),
            pl.BlockSpec((1, block_k, D), lambda t, ki: (kvh(t), ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda t, ki: (kvh(t), ki, 0)),
            pl.BlockSpec((1, block_q, D), lambda t, ki: (bh(t), qi(t), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda t, ki: (bh(t), qi(t), 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, Sq, D), o.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        input_output_aliases={3: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(q, k, v, o)
