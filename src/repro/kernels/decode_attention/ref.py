"""Pure-jnp oracle for decode attention (GQA, per-row valid lengths)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lens):
    """q: [B,Hq,D]; caches: [B,S,Hk,D]; lens: [B] int32 -> [B,Hq,D]."""
    B, Hq, D = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Hk, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    s = s / jnp.sqrt(D).astype(jnp.float32)
    valid = jnp.arange(S)[None, :] < lens[:, None]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)
