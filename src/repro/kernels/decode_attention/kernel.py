"""Atomizable GQA decode attention — one new token against a KV cache.

Layout (kernel-internal): rows = B*Hkv "request-head" units.

    q   [R, G, D]       (G = q heads per kv head)
    k,v [R, S, D]
    len [R] int32       valid cache length per row (continuous batching)
    out [R, G, D]

Grid = (num_rows, nK): row-major over schedulable rows, sequential online-
softmax accumulation over KV blocks of ``block_k``.  An *atom* executes rows
``[start, start+num_rows)`` — the TPU-native form of LithOS §4.4 atomization
for the decode hot loop (each row is one "thread block": it touches its own
KV stripe only, so disjoint row ranges compose exactly).

The running output is passed in and aliased (``input_output_aliases``) so
rows outside the atom pass through untouched.

Memory behaviour: decode attention is HBM-bound (reads S*D keys+values per
row for O(S*D) flops); the kernel streams KV through VMEM in (block_k, D)
tiles with f32 online-softmax state in scratch — the TPU analogue of the
paper's "memory-bound kernels are frequency-insensitive" class (§4.6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_in_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, nk: int, block_k: int,
                        sm_scale: float):
    k_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [G, D]
    kb = k_ref[0].astype(jnp.float32)                 # [block_k, D]
    vb = v_ref[0].astype(jnp.float32)                 # [block_k, D]
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                                   # [G, block_k]
    kpos = k_idx * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)
    valid = kpos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                                # [G, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                             # [G, block_k]
    corr = jnp.exp(m_prev - m_new)                     # [G, 1]
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(k_idx == nk - 1)
    def _flush():
        l = jnp.where(l_ref[...] == 0.0, 1.0, l_ref[...])
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_atom(q, k, v, lens, o, *, start: int, num_rows: int,
                          block_k: int = 512, interpret: bool = False):
    """Execute one atom: rows [start, start+num_rows) of decode attention.

    q: [R,G,D]; k/v: [R,S,D]; lens: [R] int32; o: running output [R,G,D]
    (aliased).  S must divide by block_k (ops pads)."""
    R, G, D = q.shape
    S = k.shape[1]
    assert k.shape == (R, S, D) and v.shape == (R, S, D)
    assert lens.shape == (R,) and o.shape == (R, G, D)
    assert S % block_k == 0, (S, block_k)
    assert 0 <= start and start + num_rows <= R, (start, num_rows, R)
    nk = S // block_k
    sm_scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(_decode_attn_kernel, nk=nk, block_k=block_k,
                               sm_scale=sm_scale)
    lens2 = lens.reshape(R, 1)
    return pl.pallas_call(
        kernel,
        grid=(num_rows, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda r, k: (start + r, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, D), lambda r, k: (start + r, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda r, k: (start + r, k, 0)),
            pl.BlockSpec((1, block_k, D), lambda r, k: (start + r, k, 0)),
            pl.BlockSpec((1, G, D), lambda r, k: (start + r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda r, k: (start + r, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, G, D), o.dtype),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, D), jnp.float32)],
        input_output_aliases={4: 0},
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(lens2, q, k, v, o)
