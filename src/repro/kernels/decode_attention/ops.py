"""jit'd wrapper: model-layout decode attention as a schedule of atoms.

Model layout q [B,Hq,D], caches [B,S,Hk,D], lens [B] -> [B,Hq,D].
Rows (B*Hk) are the schedulable units; ``n_atoms`` splits them into
contiguous ranges executed as independent pallas_calls (the LithOS
dispatcher's schedule)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.atom_matmul.ops import atom_ranges
from repro.kernels.decode_attention.kernel import decode_attention_atom


@functools.partial(jax.jit, static_argnames=("n_atoms", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, lens, *, n_atoms: int = 1,
                     block_k: int = 512, interpret: bool = False):
    B, Hq, D = q.shape
    S, Hk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hk
    block_k = min(block_k, max(S, 16))
    pad = (-S) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = k_cache.shape[1]

    # [B,Hq,D] -> [B,Hk,G,D] -> [R,G,D];  [B,S,Hk,D] -> [R,S,D]
    qf = q.reshape(B, Hk, G, D).reshape(B * Hk, G, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Hk, Sp, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Hk, Sp, D)
    lf = jnp.repeat(lens.astype(jnp.int32), Hk)

    R = B * Hk
    o = jnp.zeros_like(qf)
    for start, ln in atom_ranges(R, n_atoms):
        o = decode_attention_atom(qf, kf, vf, lf, o, start=start,
                                  num_rows=ln, block_k=block_k,
                                  interpret=interpret)
    return o.reshape(B, Hk, G, D).reshape(B, Hq, D)
