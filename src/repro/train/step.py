"""train_step factory: remat, microbatch accumulation, optional int8
gradient compression with error feedback, sharding-aware.

``make_train_step(cfg, ...)`` returns ``(init_state, train_step)`` where
``train_step(state, batch) -> (state, metrics)`` is pure and pjit-able.
Microbatching scans over ``n_micro`` slices of the global batch,
accumulating grads in fp32 (HLO stays O(1) in n_micro).  Gradient
compression quantizes the accumulated grads to int8 blocks before the
(conceptual) data-axis reduction and keeps the quantization error as
feedback added to the next step — halving data-parallel collective bytes
at equal asymptotic convergence (error feedback is unbiased in the limit).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.registry import init_model, train_loss
from repro.optim.optimizers import (AdamWConfig, OptState, adamw_init,
                                    adamw_update, dequantize, quantize)
from repro.optim.schedules import cosine_schedule

PyTree = Any


@dataclass(frozen=True)
class TrainConfig:
    remat: str = "none"              # none | dots | full
    n_micro: int = 1
    loss_chunk: int = 512
    attn_block: int = 512
    grad_compress: bool = False      # int8 + error feedback
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class TrainState(NamedTuple):
    params: PyTree
    opt: OptState
    err_fb: Optional[PyTree]         # error-feedback residual (compression)


def _split_micro(batch: dict, n: int) -> dict:
    """[B, ...] -> [n, B//n, ...] for scanning."""
    def f(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape((n, B // n) + x.shape[1:])
    return {k: f(v) for k, v in batch.items()}


def _compress_grads(grads: PyTree, err: PyTree) -> tuple[PyTree, PyTree]:
    """int8 block quantization with error feedback.  Returns (decoded
    grads as would arrive post-all-reduce, new residual)."""
    def leaf(g, e):
        g32 = g.astype(jnp.float32) + e
        q = quantize(g32)
        dec = dequantize(q)
        return dec, g32 - dec
    out = jax.tree.map(leaf, grads, err)
    dec = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    return dec, new_err


def make_train_step(cfg: ArchConfig, tc: TrainConfig = TrainConfig()):
    opt_cfg = AdamWConfig(lr=tc.lr, weight_decay=tc.weight_decay,
                          grad_clip=tc.grad_clip,
                          moment_dtype=tc.moment_dtype)

    def init_state(key) -> TrainState:
        params = init_model(cfg, key)
        opt = adamw_init(params, opt_cfg)
        err = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
               if tc.grad_compress else None)
        return TrainState(params, opt, err)

    def loss_fn(params, micro):
        loss, metrics = train_loss(params, cfg, micro, remat=tc.remat,
                                   loss_chunk=tc.loss_chunk,
                                   attn_block=tc.attn_block)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        params = state.params
        if tc.n_micro > 1:
            micro = _split_micro(batch, tc.n_micro)

            def body(acc, mb):
                (loss, metrics), g = grad_fn(params, mb)
                g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 acc[0], g)
                return (g, acc[1] + loss), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / tc.n_micro, gsum)
            loss = lsum / tc.n_micro
            metrics = {"ce": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        err_fb = state.err_fb
        if tc.grad_compress:
            grads, err_fb = _compress_grads(grads, err_fb)

        lr = cosine_schedule(state.opt.step, tc.lr, tc.total_steps,
                             tc.warmup_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state.opt, opt_cfg, lr)
        out = {"loss": loss, "lr": lr, **metrics, **opt_metrics}
        return TrainState(new_params, new_opt, err_fb), out

    return init_state, train_step
