"""Uniform model API over all assigned architecture families.

Every architecture (decoder-only dense/MoE/SSM/hybrid/VLM and enc-dec audio)
is driven through four entry points so the training loop, serving engine,
dry-run, and workload compiler never branch on family:

    init_model(cfg, key)                          -> params
    train_loss(params, cfg, batch, **opts)        -> (loss, aux)
    serve_prefill(params, cfg, batch, max_len)    -> (logits, caches)
    serve_decode(params, cfg, token, pos, caches) -> (logits, caches)

``batch`` contents by frontend (see ``configs.base.ArchConfig.frontend``):
    none        {"tokens": [B,S] i32, "labels": [B,S] i32}
    patch_stub  {"input_embeds": [B,S,D], "labels": [B,S] i32}   (VLM)
    frame_stub  {"frames": [B,Ssrc,D], "tokens": [B,St] i32,
                 "labels": [B,St] i32}                            (audio)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer

PyTree = Any

AUX_LOSS_WEIGHTS = {"lb": 0.01, "z": 1e-3}   # Switch-style MoE aux weights


def init_model(cfg: ArchConfig, key) -> PyTree:
    if cfg.is_encoder_decoder:
        return encdec.init_encdec(cfg, key)
    return transformer.init_lm(cfg, key)


def train_loss(params, cfg: ArchConfig, batch, *, remat: str = "none",
               loss_chunk: int = 512, attn_block: int = 512):
    """Mean next-token CE (+ weighted MoE aux losses).  Returns (loss, metrics)."""
    if cfg.is_encoder_decoder:
        h, (lb, zl) = encdec.forward(params, cfg, batch["frames"], batch["tokens"])
        # enc-dec loss projects through the tied embedding.
        ce = _encdec_loss(params, cfg, h, batch["labels"], chunk=loss_chunk)
    else:
        h, (lb, zl) = transformer.forward(
            params, cfg, batch.get("tokens"),
            input_embeds=batch.get("input_embeds"), remat=remat,
            attn_block=attn_block)
        ce = transformer.lm_loss(params, cfg, h, batch["labels"], chunk=loss_chunk)
    loss = ce + AUX_LOSS_WEIGHTS["lb"] * lb + AUX_LOSS_WEIGHTS["z"] * zl
    return loss, {"ce": ce, "lb_loss": lb, "z_loss": zl}


def _encdec_loss(params, cfg, h, labels, chunk: int = 512):
    logits = encdec.lm_logits(params, cfg, h)          # [B,S,V] f32 (whisper V small)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def serve_prefill(params, cfg: ArchConfig, batch, *, max_len: int,
                  attn_block: int = 512):
    if cfg.is_encoder_decoder:
        enc_out = encdec.encode(params, cfg, batch["frames"])
        B = batch["frames"].shape[0]
        caches = encdec.init_dec_caches(params, cfg, enc_out, B, max_len)
        tok0 = batch["tokens"][:, 0] if "tokens" in batch else jnp.zeros((B,), jnp.int32)
        return encdec.decode_step(params, cfg, tok0, jnp.int32(0), caches)
    return transformer.prefill(
        params, cfg, batch.get("tokens"), input_embeds=batch.get("input_embeds"),
        max_len=max_len, attn_block=attn_block)


def serve_decode(params, cfg: ArchConfig, token, pos_scalar, caches):
    if cfg.is_encoder_decoder:
        return encdec.decode_step(params, cfg, token, pos_scalar, caches)
    return transformer.decode_step(params, cfg, token, pos_scalar, caches)


def param_logical_axes(params: PyTree) -> PyTree:
    from repro.models.common import logical_axes
    return logical_axes(params)
