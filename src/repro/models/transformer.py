"""Unified decoder-only LM covering dense / MoE / hybrid / SSM / VLM configs.

Layer stacks are *pattern-grouped scans*: the per-arch layer pattern (e.g.
``("rec","rec","attn")`` for RecurrentGemma, ``("mlstm",)*7+("slstm",)`` for
xLSTM, ``("attn",)`` for dense) is the scan body; params are stacked over
``n_groups = n_layers // len(pattern)`` so HLO size is O(1) in depth.  The
remainder ``n_layers % len(pattern)`` layers are applied unrolled.

Entry points:
    init_lm(cfg, key)                  -> params
    forward(params, cfg, tokens, ...)  -> final hidden states [B,S,D]
    lm_logits / lm_loss                -> chunked vocab projection (never
                                          materializes [B,S,V])
    prefill(...) / decode_step(...)    -> serving paths with caches/states
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.common import KeyGen, dtype_of
from repro.models.layers import (apply_head, apply_mlp, apply_norm, embed_tokens,
                                 init_embed, init_head, init_mlp, init_norm)
from repro.models.moe import apply_moe, init_moe
from repro.models.sharding import shard_act

PyTree = Any


def layer_pattern(cfg: ArchConfig) -> tuple[str, ...]:
    if cfg.hybrid is not None:
        return cfg.hybrid.pattern
    return ("attn",)


def _window_for(cfg: ArchConfig, kind: str) -> int:
    if kind == "attn" and cfg.hybrid is not None:
        return cfg.hybrid.window
    return 0


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(keys: KeyGen, cfg: ArchConfig, kind: str) -> PyTree:
    dt = dtype_of(cfg.dtype)
    d = cfg.d_model
    p: dict = {"ln1": init_norm(keys, d, cfg.norm, dt)}
    if kind == "attn":
        p["attn"] = attn_lib.init_attention(
            keys, d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dt, cfg.qkv_bias)
        p["ln2"] = init_norm(keys, d, cfg.norm, dt)
        if cfg.moe is not None:
            p["moe"] = init_moe(keys, d, cfg.moe, dt)
        else:
            p["mlp"] = init_mlp(keys, d, cfg.d_ff, cfg.activation, dt)
    elif kind == "rec":
        h = cfg.hybrid
        p["rec"] = rglru_lib.init_rglru_block(keys, d, h.lru_width or d, h.conv_width, dt)
        p["ln2"] = init_norm(keys, d, cfg.norm, dt)
        if cfg.moe is not None:
            p["moe"] = init_moe(keys, d, cfg.moe, dt)
        else:
            p["mlp"] = init_mlp(keys, d, cfg.d_ff, cfg.activation, dt)
    elif kind == "mlstm":
        p["mlstm"] = ssm_lib.init_mlstm_block(keys, d, cfg.n_heads, cfg.hybrid.conv_width, dt)
    elif kind == "slstm":
        p["slstm"] = ssm_lib.init_slstm_block(keys, d, cfg.n_heads, dt)
    else:
        raise ValueError(kind)
    return p


def init_lm(cfg: ArchConfig, key) -> PyTree:
    keys = KeyGen(key)
    dt = dtype_of(cfg.dtype)
    pat = layer_pattern(cfg)
    p_len = len(pat)
    n_groups, n_rem = cfg.n_layers // p_len, cfg.n_layers % p_len

    params: dict = {"embed": init_embed(keys, cfg.vocab_size, cfg.d_model, dt)}
    if not cfg.tie_embeddings:
        params["head"] = init_head(keys, cfg.d_model, cfg.vocab_size, dt)
    params["final_norm"] = init_norm(keys, cfg.d_model, cfg.norm, dt)
    if cfg.frontend == "patch_stub":
        from repro.models.common import normal_init
        params["vlm_proj"] = {"w": normal_init(keys(), (cfg.d_model, cfg.d_model), dt)}

    blocks = {}
    if n_groups:
        for pos, kind in enumerate(pat):
            stacked = [_init_block(keys, cfg, kind) for _ in range(n_groups)]
            blocks[str(pos)] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    params["blocks"] = blocks
    if n_rem:
        params["rem"] = {str(i): _init_block(keys, cfg, pat[i]) for i in range(n_rem)}
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill hidden states)
# ---------------------------------------------------------------------------

def _apply_block(bp, x, cfg: ArchConfig, kind: str, positions, *,
                 block_skip: bool = True, attn_block: int = 512,
                 mlstm_chunk: int = 256):
    """Residual block application on [B,S,D] activations."""
    window = _window_for(cfg, kind)
    aux = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if kind == "attn":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        q, k, v = attn_lib.qkv_project(bp["attn"], h, positions, cfg.rope_theta)
        q = shard_act(q, "act_bthd")
        o = attn_lib.blocked_attention(
            q, k, v, causal=True, window=window,
            block_q=attn_block, block_kv=attn_block, block_skip=block_skip)
        x = x + attn_lib.out_project(bp["attn"], o)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        if cfg.moe is not None:
            mo, aux = apply_moe(bp["moe"], h, cfg.moe)
            x = x + mo
        else:
            x = x + apply_mlp(bp["mlp"], h, cfg.activation)
    elif kind == "rec":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        x = x + rglru_lib.apply_rglru_block(bp["rec"], h)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        x = x + apply_mlp(bp["mlp"], h, cfg.activation)
    elif kind == "mlstm":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        x = x + ssm_lib.apply_mlstm_block(bp["mlstm"], h, chunk=mlstm_chunk)
    elif kind == "slstm":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        x = x + ssm_lib.apply_slstm_block(bp["slstm"], h)
    return x, aux


def embed_inputs(params, cfg: ArchConfig, tokens=None, input_embeds=None):
    if input_embeds is not None:
        x = input_embeds
        if "vlm_proj" in params:
            from repro.models.common import dot
            x = dot(x, params["vlm_proj"]["w"])
    else:
        x = embed_tokens(params["embed"], tokens)
    return x


def forward(params, cfg: ArchConfig, tokens=None, *, input_embeds=None,
            remat: str = "none", block_skip: bool = True,
            attn_block: int = 512) -> tuple[jax.Array, tuple]:
    """Token/embedding inputs -> final-norm hidden states [B,S,D] + aux losses."""
    x = embed_inputs(params, cfg, tokens, input_embeds)
    x = shard_act(x, "act_btd")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    pat = layer_pattern(cfg)

    def group_body(carry, gp):
        x, lb, zl = carry
        for pos, kind in enumerate(pat):
            x, (a_lb, a_zl) = _apply_block(
                gp[str(pos)], x, cfg, kind, positions,
                block_skip=block_skip, attn_block=attn_block)
            lb, zl = lb + a_lb, zl + a_zl
        x = shard_act(x, "act_btd")
        return (x, lb, zl), None

    body = group_body
    if remat == "full":
        body = jax.checkpoint(group_body)
    elif remat == "dots":
        body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    zero = jnp.zeros((), jnp.float32)
    if params.get("blocks"):
        (x, lb, zl), _ = jax.lax.scan(body, (x, zero, zero), params["blocks"])
    else:
        lb = zl = zero
    for i in sorted(params.get("rem", {})):
        x, (a_lb, a_zl) = _apply_block(
            params["rem"][i], x, cfg, pat[int(i)], positions,
            block_skip=block_skip, attn_block=attn_block)
        lb, zl = lb + a_lb, zl + a_zl
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, (lb, zl)


# ---------------------------------------------------------------------------
# Vocab projection: chunked (never materializes [B,S,V])
# ---------------------------------------------------------------------------

def lm_logits(params, cfg: ArchConfig, h):
    head = params.get("head")
    emb = params["embed"] if head is None else None
    return apply_head(head, h, emb, cfg.logit_softcap)


def lm_loss(params, cfg: ArchConfig, h, labels, *, chunk: int = 512,
            mask=None) -> jax.Array:
    """Mean next-token cross-entropy with seq-chunked vocab projection."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    Sp = h.shape[1]
    nC = Sp // chunk
    hc = h.reshape(B, nC, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, nC, chunk).swapaxes(0, 1)
    mc = (mask.reshape(B, nC, chunk).swapaxes(0, 1) if mask is not None
          else (lc >= 0))

    @jax.checkpoint
    def chunk_loss(hx, lx, mx):
        logits = lm_logits(params, cfg, hx)          # [B,chunk,V] f32
        logits = shard_act(logits, "act_btv")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(lx, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mx
        return nll.sum(), mx.sum()

    def body(carry, xs):
        tot, cnt = carry
        s, c = chunk_loss(*xs)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc.astype(jnp.float32)))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Serving: caches and states
# ---------------------------------------------------------------------------
# Cache structure (plain dict, scan-compatible):
#   {"groups": {pos: stacked-cache [G,...]}, "rem": {i: cache}}
# where pos indexes the layer pattern and rem the remainder layers.

def _init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int):
    dt = dtype_of(cfg.dtype)
    if kind == "attn":
        w = _window_for(cfg, kind)
        S = min(max_len, w) if w else max_len
        shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    if kind == "rec":
        width = cfg.hybrid.lru_width or cfg.d_model
        return (jnp.zeros((batch, width), dt),
                jnp.zeros((batch, cfg.hybrid.conv_width - 1, width), dt))
    if kind == "mlstm":
        di = int(ssm_lib.MLSTM_EXPANSION * cfg.d_model)
        hd = di // cfg.n_heads
        st = ssm_lib.init_mlstm_state(batch, cfg.n_heads, hd)
        return (st, jnp.zeros((batch, cfg.hybrid.conv_width - 1, di), dt))
    if kind == "slstm":
        hd = cfg.d_model // cfg.n_heads
        return ssm_lib.init_slstm_state(batch, cfg.n_heads, hd)
    raise ValueError(kind)


def init_caches(cfg: ArchConfig, batch: int, max_len: int):
    pat = layer_pattern(cfg)
    G = cfg.n_layers // len(pat)
    n_rem = cfg.n_layers % len(pat)
    groups = {}
    if G:
        for pos, kind in enumerate(pat):
            c = _init_block_cache(cfg, kind, batch, max_len)
            groups[str(pos)] = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (G,) + x.shape), c)
    rem = {str(i): _init_block_cache(cfg, pat[i], batch, max_len)
           for i in range(n_rem)}
    return {"groups": groups, "rem": rem}


def _decode_block(bp, x, cfg, kind, pos_scalar, cache):
    """x: [B,1,D]; cache: this block's state slice.  Returns (x, new_cache)."""
    window = _window_for(cfg, kind)
    if kind == "attn":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        B = x.shape[0]
        pos_arr = jnp.asarray(pos_scalar)
        positions = (pos_arr[:, None] if pos_arr.ndim == 1
                     else jnp.broadcast_to(pos_arr, (B, 1)))
        q, k, v = attn_lib.qkv_project(bp["attn"], h, positions, cfg.rope_theta)
        kc, vc = attn_lib.update_kv_cache(
            cache["k"], cache["v"], k, v, pos_scalar, window=window)
        o = attn_lib.decode_attention(q[:, 0], kc, vc, pos_scalar + 1, window=window)
        x = x + attn_lib.out_project(bp["attn"], o[:, None])
        h = apply_norm(bp["ln2"], x, cfg.norm)
        if cfg.moe is not None:
            mo, _ = apply_moe(bp["moe"], h, cfg.moe)
            x = x + mo
        else:
            x = x + apply_mlp(bp["mlp"], h, cfg.activation)
        return x, {"k": kc, "v": vc}
    if kind == "rec":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        o, st = rglru_lib.decode_rglru_block(bp["rec"], h, cache)
        x = x + o
        h = apply_norm(bp["ln2"], x, cfg.norm)
        x = x + apply_mlp(bp["mlp"], h, cfg.activation)
        return x, st
    if kind == "mlstm":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        st, conv = cache
        o, st_new, conv_new = ssm_lib.decode_mlstm_block(bp["mlstm"], h, st, conv)
        return x + o, (st_new, conv_new)
    if kind == "slstm":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        o, st = ssm_lib.decode_slstm_block(bp["slstm"], h, cache)
        return x + o, st
    raise ValueError(kind)


def _prefill_block(bp, x, cfg, kind, positions, cache, *, block_skip, attn_block):
    """Prompt-length block application that also fills this block's cache."""
    window = _window_for(cfg, kind)
    S = x.shape[1]
    if kind == "attn":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        q, k, v = attn_lib.qkv_project(bp["attn"], h, positions, cfg.rope_theta)
        o = attn_lib.blocked_attention(
            q, k, v, causal=True, window=window,
            block_q=attn_block, block_kv=attn_block, block_skip=block_skip)
        x = x + attn_lib.out_project(bp["attn"], o)
        h = apply_norm(bp["ln2"], x, cfg.norm)
        if cfg.moe is not None:
            mo, _ = apply_moe(bp["moe"], h, cfg.moe)
            x = x + mo
        else:
            x = x + apply_mlp(bp["mlp"], h, cfg.activation)
        if window:
            keep = min(window, S)
            kc, vc = attn_lib.update_kv_cache(
                cache["k"], cache["v"], k[:, -keep:], v[:, -keep:],
                jnp.int32(max(0, S - keep)), window=window)
        else:
            kc, vc = attn_lib.update_kv_cache(cache["k"], cache["v"], k, v, jnp.int32(0))
        return x, {"k": kc, "v": vc}
    if kind == "rec":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        o, st = rglru_lib.apply_rglru_block(
            bp["rec"], h, conv_state=cache[1], return_state=True)
        x = x + o
        h = apply_norm(bp["ln2"], x, cfg.norm)
        x = x + apply_mlp(bp["mlp"], h, cfg.activation)
        return x, st
    if kind == "mlstm":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        st_in, _conv = cache
        o, st = ssm_lib.apply_mlstm_block(bp["mlstm"], h, state=st_in, return_state=True)
        return x + o, st
    if kind == "slstm":
        h = apply_norm(bp["ln1"], x, cfg.norm)
        o, st = ssm_lib.apply_slstm_block(bp["slstm"], h, state=cache, return_state=True)
        return x + o, st
    raise ValueError(kind)


def decode_step(params, cfg: ArchConfig, token, pos_scalar, caches, *,
                input_embeds=None):
    """One-token decode.  token: [B] int32 (or input_embeds [B,1,D]).
    ``pos_scalar`` may be a scalar (shared) or [B] per-slot positions
    (continuous batching).

    Returns (logits [B,V] f32, new caches).
    """
    x = embed_inputs(params, cfg, token[:, None] if token is not None else None,
                     input_embeds)
    pat = layer_pattern(cfg)

    def group_body(x, xs):
        gp, cache_slices = xs
        new_slices = {}
        for pos, kind in enumerate(pat):
            x, new_slices[str(pos)] = _decode_block(
                gp[str(pos)], x, cfg, kind, pos_scalar, cache_slices[str(pos)])
        return x, new_slices

    new_groups = caches["groups"]
    if params.get("blocks"):
        x, new_groups = jax.lax.scan(group_body, x, (params["blocks"], caches["groups"]))
    new_rem = {}
    for i in sorted(params.get("rem", {})):
        x, new_rem[i] = _decode_block(
            params["rem"][i], x, cfg, pat[int(i)], pos_scalar, caches["rem"][i])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {"groups": new_groups, "rem": new_rem}


def prefill(params, cfg: ArchConfig, tokens, *, input_embeds=None,
            max_len: Optional[int] = None, block_skip: bool = True,
            attn_block: int = 512):
    """Process a prompt, filling caches.  Returns (last-position logits, caches)."""
    x = embed_inputs(params, cfg, tokens, input_embeds)
    B, S, _ = x.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    pat = layer_pattern(cfg)
    caches = init_caches(cfg, B, max_len)

    def group_body(x, xs):
        gp, cache_slices = xs
        new_slices = {}
        for pos, kind in enumerate(pat):
            x, new_slices[str(pos)] = _prefill_block(
                gp[str(pos)], x, cfg, kind, positions, cache_slices[str(pos)],
                block_skip=block_skip, attn_block=attn_block)
        return x, new_slices

    new_groups = caches["groups"]
    if params.get("blocks"):
        x, new_groups = jax.lax.scan(group_body, x, (params["blocks"], caches["groups"]))
    new_rem = {}
    for i in sorted(params.get("rem", {})):
        x, new_rem[i] = _prefill_block(
            params["rem"][i], x, cfg, pat[int(i)], positions, caches["rem"][i],
            block_skip=block_skip, attn_block=attn_block)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params, cfg, x[:, -1:])[:, 0]
    return logits, {"groups": new_groups, "rem": new_rem}
