"""Mixture-of-Experts layer with sort-based capacity dispatch.

Dispatch is scatter/sort based (NOT the GShard one-hot einsum): the one-hot
dispatch einsum costs T*E*C*D fake FLOPs and is infeasible at 1M-token
prefill.  Here:

  1. top-k routing -> (token, expert, gate) triples, T*k of them
  2. stable-sort triples by expert id
  3. position-in-expert via exclusive-cumsum of expert counts
  4. scatter token activations into an [E, C, D] buffer (overflow dropped)
  5. grouped matmul  [E,C,D] x [E,D,F]  — real FLOPs = cf * T * k * D * F
  6. gather back + gate-weighted combine

Expert parallelism: shard the leading E dim of the buffers/weights over the
``model`` axis (``moe.parallelism == "ep"``); XLA inserts the all-to-alls at
the scatter/gather boundaries.  TP-in-expert (``"tp"``) shards F instead.
Load-balance + router-z auxiliary losses follow Switch/ST-MoE.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, einsum, fan_in_init, normal_init
from repro.models.layers import apply_mlp, init_mlp
from repro.configs.base import MoEConfig


def init_moe(keys: KeyGen, d: int, cfg: MoEConfig, dtype):
    p = {
        "router": normal_init(keys(), (d, cfg.n_experts), dtype, scale=0.02),
        "wi": normal_init(keys(), (cfg.n_experts, d, cfg.expert_d_ff), dtype),
        "wg": normal_init(keys(), (cfg.n_experts, d, cfg.expert_d_ff), dtype),
        "wo": fan_in_init(keys(), (cfg.n_experts, cfg.expert_d_ff, d), dtype, fan_axis=1),
    }
    if cfg.n_shared_experts:
        f_shared = cfg.shared_d_ff * cfg.n_shared_experts
        p["shared_wi"] = normal_init(keys(), (d, f_shared), dtype)
        p["shared_wg"] = normal_init(keys(), (d, f_shared), dtype)
        p["shared_wo"] = fan_in_init(keys(), (f_shared, d), dtype)
    return p


def _capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def _moe_grouped(params, xg, cfg: MoEConfig, C: int):
    """Grouped dispatch + expert MLP.  xg: [G, T, D] with G = batch rows.

    Groups keep every sort/scatter local to a data shard under GSPMD —
    global (flat-token) dispatch contracts over the data-sharded token dim
    and all-reduces an [E, C, ff]-sized buffer per layer per microbatch
    (the dominant collective in the MoE train dry-runs before grouping —
    EXPERIMENTS.md §Perf).  Explicit shard_dims constraints pin the G dim
    to the data axes; scatters/gathers batch over it."""
    from repro.models.sharding import shard_dims
    G, T, D = xg.shape
    E, K = cfg.n_experts, cfg.top_k

    logits = einsum("gtd,de->gte", xg, params["router"],
                    out_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                       # [G,T,E]
    gate_vals, expert_ids = jax.lax.top_k(probs, K)               # [G,T,K]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux losses (Switch LB + router z), averaged over groups
    me = probs.mean(axis=1)                                       # [G,E]
    one_hot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)    # [G,T,K,E]
    ce = one_hot.sum(axis=(1, 2)) / (T * K)                       # [G,E]
    lb_loss = (E * (me * ce).sum(-1)).mean()
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # sort-based dispatch within each group (all ops batched over G)
    flat_eid = expert_ids.reshape(G, T * K)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(T), K)[None], (G, T * K))
    flat_gate = gate_vals.reshape(G, T * K)
    order = jnp.argsort(flat_eid, axis=1, stable=True)
    s_eid = jnp.take_along_axis(flat_eid, order, axis=1)
    s_tok = jnp.take_along_axis(flat_tok, order, axis=1)
    s_gate = jnp.take_along_axis(flat_gate, order, axis=1)

    counts = one_hot.sum(axis=(1, 2)).astype(jnp.int32)           # [G,E]
    starts = jnp.concatenate(
        [jnp.zeros((G, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]],
        axis=1)                                                   # [G,E]
    pos_in_e = (jnp.arange(T * K, dtype=jnp.int32)[None]
                - jnp.take_along_axis(starts, s_eid, axis=1))
    keep = pos_in_e < C
    slot = jnp.where(keep, s_eid * C + pos_in_e, E * C)           # drop slot

    gathered = jnp.take_along_axis(xg, s_tok[..., None], axis=1)  # [G,TK,D]
    buf = jnp.zeros((G, E * C + 1, D), xg.dtype)
    buf = buf.at[jnp.arange(G)[:, None], slot].set(gathered)
    expert_in = buf[:, :-1].reshape(G, E, C, D)
    expert_in = shard_dims(expert_in, ("dp", None, None, None))

    h = einsum("gecd,edf->gecf", expert_in, params["wi"])
    g = einsum("gecd,edf->gecf", expert_in, params["wg"])
    h = shard_dims(jax.nn.silu(g) * h, ("dp", None, None, "tp"))
    expert_out = einsum("gecf,efd->gecd", h, params["wo"])
    expert_out = shard_dims(expert_out, ("dp", None, None, None))

    flat_out = jnp.concatenate(
        [expert_out.reshape(G, E * C, D),
         jnp.zeros((G, 1, D), expert_out.dtype)], axis=1)
    picked = jnp.take_along_axis(flat_out, slot[..., None], axis=1)
    contrib = picked.astype(jnp.float32) * s_gate[..., None]
    out = jnp.zeros((G, T, D), jnp.float32)
    out = out.at[jnp.arange(G)[:, None], s_tok].add(contrib)
    return out, lb_loss, z_loss


def apply_moe(params, x, cfg: MoEConfig, *, rng: Optional[jax.Array] = None):
    """x: [B,S,D] (or [T,D]).  Returns (out, aux) with aux = (lb, z).

    GShard-style grouped dispatch: each batch row is a group — [G, E, C, *]
    tensors shard over the data axis with zero cross-shard dispatch."""
    orig_shape = x.shape
    D = x.shape[-1]
    if x.ndim == 3:
        B, S = x.shape[0], x.shape[1]
        xg = x
        C = _capacity(S, cfg)
    else:
        xg = x.reshape(1, -1, D)
        C = _capacity(xg.shape[1], cfg)
    out, lb_loss, z_loss = _moe_grouped(params, xg, cfg, C)
    out = out.reshape(orig_shape)

    if cfg.n_shared_experts:
        sh = {"wi": params["shared_wi"], "wg": params["shared_wg"],
              "wo": params["shared_wo"]}
        out = (out.astype(jnp.float32)
               + apply_mlp(sh, x, "swiglu").astype(jnp.float32))
    return out.astype(x.dtype), (lb_loss, z_loss)
