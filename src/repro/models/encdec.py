"""Encoder-decoder LM (Whisper-family backbone).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings ``[B, S_src, D]`` (30 s of audio = 1500 frames
post-conv).  This module implements the transformer backbone:

* encoder — non-causal self-attention, learned positions, pre-LN, GELU MLP.
* decoder — causal self-attention + cross-attention to encoder output,
  learned positions, tied embedding head (Whisper ties).

Layer stacks scan over stacked params (O(1) HLO in depth).  Serving path:
``encode`` once, then ``decode_prefill`` / ``decode_step`` with self-attn KV
caches + precomputed cross-attn K/V (computed once from encoder output —
standard Whisper serving optimization).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models.common import KeyGen, dtype_of, einsum, normal_init
from repro.models.layers import (apply_head, apply_mlp, apply_norm,
                                 embed_tokens, init_embed, init_head,
                                 init_mlp, init_norm)

PyTree = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_enc_layer(keys: KeyGen, cfg: ArchConfig, dt) -> PyTree:
    d = cfg.d_model
    return {
        "ln1": init_norm(keys, d, cfg.norm, dt),
        "attn": attn_lib.init_attention(keys, d, cfg.n_heads, cfg.n_heads,
                                        cfg.head_dim, dt, qkv_bias=True),
        "ln2": init_norm(keys, d, cfg.norm, dt),
        "mlp": init_mlp(keys, d, cfg.d_ff, "gelu", dt),
    }


def _init_dec_layer(keys: KeyGen, cfg: ArchConfig, dt) -> PyTree:
    d = cfg.d_model
    return {
        "ln1": init_norm(keys, d, cfg.norm, dt),
        "attn": attn_lib.init_attention(keys, d, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.head_dim, dt, qkv_bias=True),
        "ln_x": init_norm(keys, d, cfg.norm, dt),
        "xattn": attn_lib.init_attention(keys, d, cfg.n_heads, cfg.n_heads,
                                         cfg.head_dim, dt, qkv_bias=True),
        "ln2": init_norm(keys, d, cfg.norm, dt),
        "mlp": init_mlp(keys, d, cfg.d_ff, "gelu", dt),
    }


def init_encdec(cfg: ArchConfig, key) -> PyTree:
    keys = KeyGen(key)
    dt = dtype_of(cfg.dtype)
    d = cfg.d_model
    params: dict = {
        # frame embeddings arrive pre-computed (conv frontend stub); encoder
        # adds sinusoid-initialized learned positions.
        "enc_pos": normal_init(keys(), (cfg.max_source_positions, d), dt),
        "embed": init_embed(keys, cfg.vocab_size, d, dt),
        "dec_pos": normal_init(keys(), (448, d), dt),   # whisper decoder ctx
        "enc_final_norm": init_norm(keys, d, cfg.norm, dt),
        "final_norm": init_norm(keys, d, cfg.norm, dt),
    }
    enc = [_init_enc_layer(keys, cfg, dt) for _ in range(cfg.n_encoder_layers)]
    dec = [_init_dec_layer(keys, cfg, dt) for _ in range(cfg.n_layers)]
    params["enc_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
    params["dec_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dec)
    return params


# ---------------------------------------------------------------------------
# Attention sub-blocks (MHA, no RoPE — whisper uses learned positions)
# ---------------------------------------------------------------------------

def _self_attn(p, x, *, causal: bool):
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    q, k, v = attn_lib.qkv_project(p, x, pos, 0.0, use_rope=False)
    o = attn_lib.blocked_attention(q, k, v, causal=causal)
    return attn_lib.out_project(p, o)


def _cross_attn(p, x, enc_kv):
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    q, _, _ = attn_lib.qkv_project(p, x, pos, 0.0, use_rope=False)
    k, v = enc_kv
    o = attn_lib.blocked_attention(q, k, v, causal=False)
    return attn_lib.out_project(p, o)


def _xattn_kv(p, enc_out):
    """Precompute cross-attention K/V from encoder output (once per request)."""
    k = einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = einsum("btd,dhk->bthk", enc_out, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def encode(params, cfg: ArchConfig, frames) -> jax.Array:
    """frames: [B, S_src, D] precomputed embeddings -> encoder states."""
    S = frames.shape[1]
    x = frames + params["enc_pos"][:S]

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + _self_attn(lp["attn"], h, causal=False)
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, "gelu")
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_final_norm"], x, cfg.norm)


# ---------------------------------------------------------------------------
# Decoder: teacher-forced forward (train) and serving paths
# ---------------------------------------------------------------------------

def decoder_forward(params, cfg: ArchConfig, tokens, enc_out) -> jax.Array:
    """Teacher-forced decoder pass -> hidden states [B,S,D]."""
    S = tokens.shape[1]
    x = embed_tokens(params["embed"], tokens) + params["dec_pos"][:S]

    def body(x, lp):
        h = apply_norm(lp["ln1"], x, cfg.norm)
        x = x + _self_attn(lp["attn"], h, causal=True)
        h = apply_norm(lp["ln_x"], x, cfg.norm)
        x = x + _cross_attn(lp["xattn"], h, _xattn_kv(lp["xattn"], enc_out))
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, "gelu")
        return x, None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return apply_norm(params["final_norm"], x, cfg.norm)


def forward(params, cfg: ArchConfig, frames, tokens):
    """(frames, target tokens) -> (hidden states, aux) for loss computation."""
    enc_out = encode(params, cfg, frames)
    h = decoder_forward(params, cfg, tokens, enc_out)
    zero = jnp.zeros((), jnp.float32)
    return h, (zero, zero)


def lm_logits(params, cfg: ArchConfig, h):
    return apply_head(None, h, params["embed"], cfg.logit_softcap)  # tied


# -- serving ---------------------------------------------------------------

def init_dec_caches(params, cfg: ArchConfig, enc_out, batch: int, max_len: int):
    """Self-attn KV caches + precomputed cross-attn K/V per layer."""
    dt = dtype_of(cfg.dtype)
    L = cfg.n_layers
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    xk, xv = jax.vmap(lambda lp: _xattn_kv(lp, enc_out))(
        params["dec_layers"]["xattn"])
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "xk": xk, "xv": xv}


def decode_step(params, cfg: ArchConfig, token, pos_scalar, caches):
    """One-token decode.  token: [B] int32 -> (logits [B,V], new caches)."""
    B = token.shape[0]
    x = embed_tokens(params["embed"], token[:, None])
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos_scalar, 1)[None]

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        h = apply_norm(lp["ln1"], x, cfg.norm)
        pos = jnp.broadcast_to(pos_scalar, (B, 1))
        q, k, v = attn_lib.qkv_project(lp["attn"], h, pos, 0.0, use_rope=False)
        kc, vc = attn_lib.update_kv_cache(kc, vc, k, v, pos_scalar)
        o = attn_lib.decode_attention(q[:, 0], kc, vc, pos_scalar + 1)
        x = x + attn_lib.out_project(lp["attn"], o[:, None])
        h = apply_norm(lp["ln_x"], x, cfg.norm)
        qx, _, _ = attn_lib.qkv_project(lp["xattn"], h, pos, 0.0, use_rope=False)
        S_src = xk.shape[1]
        ox = attn_lib.decode_attention(qx[:, 0], xk, xv, jnp.int32(S_src))
        x = x + attn_lib.out_project(lp["xattn"], ox[:, None])
        h = apply_norm(lp["ln2"], x, cfg.norm)
        x = x + apply_mlp(lp["mlp"], h, "gelu")
        return x, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["dec_layers"], caches["k"], caches["v"],
                  caches["xk"], caches["xv"]))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {**caches, "k": kc, "v": vc}
