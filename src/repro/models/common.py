"""Shared model infrastructure: param trees, initializers, logical axes, dtype helpers.

All models are pure-functional JAX: ``init_*`` builds a nested-dict param tree;
apply functions take ``(params, inputs)``.  Sharding is expressed through
*logical axes*: every param leaf has a name-path, and ``logical_axes()`` maps
paths to logical dimension names which ``sharding.py`` resolves to mesh axes.
"""
from __future__ import annotations

import math
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16, "int8": jnp.int8}[name]


# ---------------------------------------------------------------------------
# Initializers (seeded, shape-aware)
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def fan_in_init(key, shape, dtype, fan_axis: int = 0):
    fan_in = shape[fan_axis] if shape else 1
    scale = 1.0 / math.sqrt(max(1, fan_in))
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


class KeyGen:
    """Deterministic key splitter so init order changes don't reshuffle seeds."""

    def __init__(self, key):
        self._key = key
        self._n = 0

    def __call__(self):
        self._n += 1
        return jax.random.fold_in(self._key, self._n)


# ---------------------------------------------------------------------------
# High-precision contraction helpers
# ---------------------------------------------------------------------------
# XLA:CPU's DotThunk cannot execute some fused BF16xBF16=F32 dots (it surfaces
# inside lax.scan bodies).  ``REPRO_SAFE_DOT`` controls an upcast-to-f32
# workaround: "auto" (default) enables it only on the CPU backend; the dry-run
# sets it to "0" so lowered TPU programs keep pure-bf16 dots (dry-runs never
# execute, so the thunk limitation is irrelevant there).

import os as _os


def _safe_dot() -> bool:
    mode = _os.environ.get("REPRO_SAFE_DOT", "auto")
    if mode == "auto":
        return jax.default_backend() == "cpu"
    return mode == "1"


def dot(x, w):
    """Matmul with f32 accumulation, output in x.dtype."""
    if _safe_dot() and x.dtype == jnp.bfloat16:
        return jnp.matmul(x.astype(jnp.float32),
                          w.astype(jnp.float32)).astype(x.dtype)
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def einsum(spec, *args, out_dtype=None):
    dt = out_dtype if out_dtype is not None else args[0].dtype
    if _safe_dot() and any(a.dtype == jnp.bfloat16 for a in args):
        out = jnp.einsum(spec, *(a.astype(jnp.float32) for a in args))
        return out.astype(dt)
    out = jnp.einsum(spec, *args, preferred_element_type=jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Logical axes by param path
# ---------------------------------------------------------------------------
# Rules are (regex-on-path, axes-tuple).  Paths look like
# "layers/attn/wq", "embed/tok", "layers/moe/wi", ...  A leading "L" axis is
# automatically added for stacked (scanned) layer params.

AXIS_RULES: list[tuple[str, tuple[str, ...]]] = [
    (r".*embed/tok$", ("vocab", "embed")),
    (r".*embed/pos$", (None, "embed")),
    (r".*head/w$", ("embed", "vocab")),
    (r".*(attn|xattn)/wq$", ("embed", "q_heads", "head")),
    (r".*(attn|xattn)/wk$", ("embed", "kv_heads", "head")),
    (r".*(attn|xattn)/wv$", ("embed", "kv_heads", "head")),
    (r".*(attn|xattn)/wo$", ("q_heads", "head", "embed")),
    (r".*(attn|xattn)/bq$", ("q_heads", "head")),
    (r".*(attn|xattn)/bk$", ("kv_heads", "head")),
    (r".*(attn|xattn)/bv$", ("kv_heads", "head")),
    (r".*mlp/wi$", ("embed", "ff")),
    (r".*mlp/wg$", ("embed", "ff")),
    (r".*mlp/wo$", ("ff", "embed")),
    (r".*moe/router$", ("embed", "experts")),
    (r".*moe/wi$", ("experts", "embed", "expert_ff")),
    (r".*moe/wg$", ("experts", "embed", "expert_ff")),
    (r".*moe/wo$", ("experts", "expert_ff", "embed")),
    (r".*moe/shared_wi$", ("embed", "ff")),
    (r".*moe/shared_wg$", ("embed", "ff")),
    (r".*moe/shared_wo$", ("ff", "embed")),
    # RG-LRU recurrent block
    (r".*rec/w_in$", ("embed", "rnn")),
    (r".*rec/w_gate_in$", ("embed", "rnn")),
    (r".*rec/conv_w$", (None, "rnn")),
    (r".*rec/conv_b$", ("rnn",)),
    (r".*rec/w_a$", ("rnn", "rnn_heads")),
    (r".*rec/w_i$", ("rnn", "rnn_heads")),
    (r".*rec/lam$", ("rnn",)),
    (r".*rec/w_out$", ("rnn", "embed")),
    # mLSTM / sLSTM
    (r".*mlstm/w_up$", ("embed", "ff")),
    (r".*mlstm/w_(q|k|v)$", ("ff", "q_heads", "head")),
    (r".*mlstm/w_(ig|fg)$", ("ff", "q_heads")),
    (r".*mlstm/b_(ig|fg)$", ("q_heads",)),
    (r".*mlstm/conv_w$", (None, "ff")),
    (r".*mlstm/w_down$", ("ff", "embed")),
    (r".*slstm/w_(i|f|z|o)$", ("embed", "q_heads", "head")),
    (r".*slstm/r_(i|f|z|o)$", ("q_heads", "head", "head")),
    (r".*slstm/b_(i|f|z|o)$", ("q_heads", "head")),
    (r".*slstm/ffn_wi$", ("embed", "ff")),
    (r".*slstm/ffn_wg$", ("embed", "ff")),
    (r".*slstm/ffn_wo$", ("ff", "embed")),
    # norms / misc
    (r".*(norm|ln)[^/]*/scale$", ("embed",)),
    (r".*(norm|ln)[^/]*/bias$", ("embed",)),
    (r".*vlm_proj/w$", ("embed", "embed2")),
]


def logical_axes_for_path(path: str, ndim: int) -> tuple:
    for pat, axes in AXIS_RULES:
        if re.match(pat, path):
            if len(axes) == ndim:
                return axes
            if len(axes) == ndim - 1:
                # stacked (scanned) layer param: leading layer axis
                return ("layers",) + axes
    return (None,) * ndim


def tree_paths(tree: PyTree, prefix: str = "") -> list[tuple[str, Any]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(tree_paths(tree[k], f"{prefix}/{k}" if prefix else k))
    else:
        out.append((prefix, tree))
    return out


def logical_axes(params: PyTree) -> PyTree:
    """Mirror tree of logical-axis tuples for a param tree."""

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}/{k}" if prefix else k) for k, v in tree.items()}
        return logical_axes_for_path(prefix, np.ndim(tree) if not hasattr(tree, "ndim") else tree.ndim)

    return walk(params, "")


def count_params(params: PyTree) -> int:
    return sum(int(np.prod(x.shape)) for _, x in tree_paths(params) if hasattr(x, "shape"))


def cast_tree(params: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
