"""Attention: GQA with RoPE; memory-efficient blocked implementations.

Three execution paths:

* ``naive_attention``      — O(S^2) reference; oracle for tests, decode path.
* ``blocked_attention``    — pure-jnp online-softmax flash (lax.scan over KV
                             blocks).  Causal uses a *triangular* iteration
                             space (no masked-out block is ever computed) when
                             ``block_skip=True``; sliding-window iterates only
                             blocks inside the window.  This is the dry-run /
                             TPU-lowering path.
* Pallas flash kernel      — ``repro.kernels.flash_attention`` (TPU target,
                             validated in interpret mode); selected by the
                             runtime when ``use_pallas=True``.

All math accumulates in f32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, einsum, fan_in_init, normal_init, zeros_init
from repro.models.layers import apply_rope


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(keys: KeyGen, d: int, n_heads: int, n_kv: int, head_dim: int,
                   dtype, qkv_bias: bool = False):
    p = {
        "wq": normal_init(keys(), (d, n_heads, head_dim), dtype),
        "wk": normal_init(keys(), (d, n_kv, head_dim), dtype),
        "wv": normal_init(keys(), (d, n_kv, head_dim), dtype),
        "wo": fan_in_init(keys(), (n_heads, head_dim, d), dtype),
    }
    if qkv_bias:
        p["bq"] = zeros_init(keys(), (n_heads, head_dim), dtype)
        p["bk"] = zeros_init(keys(), (n_kv, head_dim), dtype)
        p["bv"] = zeros_init(keys(), (n_kv, head_dim), dtype)
    return p


def qkv_project(params, x, positions, rope_theta: float, use_rope: bool = True):
    """x: [B,S,D] -> q [B,S,Hq,Dh], k,v [B,S,Hkv,Dh] (RoPE applied)."""
    q = einsum("btd,dhk->bthk", x, params["wq"])
    k = einsum("btd,dhk->bthk", x, params["wk"])
    v = einsum("btd,dhk->bthk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def out_project(params, attn_out):
    """attn_out: [B,S,Hq,Dh] -> [B,S,D]."""
    return einsum("bthk,hkd->btd", attn_out, params["wo"])


# ---------------------------------------------------------------------------
# Reference (oracle) attention
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0):
    """q: [B,Sq,Hq,Dh], k/v: [B,Sk,Hkv,Dh].  GQA via head grouping."""
    B, Sq, Hq, Dh = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Sq, Hk, G, Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s / jnp.sqrt(Dh).astype(jnp.float32)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blocked online-softmax attention (pure jnp; the lowering path)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _block_scores(qg, kb, qpos, kpos, causal, window):
    """qg: [B,bq,Hk,G,D], kb: [B,bk,Hk,D] -> masked f32 scores [B,Hk,G,bq,bk]."""
    Dh = qg.shape[-1]
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), kb.astype(jnp.float32))
    s = s / jnp.sqrt(Dh).astype(jnp.float32)
    mask = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    mask &= kpos[None, :] >= 0
    return jnp.where(mask[None, None, None], s, NEG_INF)


def _online_update(carry, s, vb):
    """One online-softmax accumulation step.

    carry: (m [B,H,G,bq], l [B,H,G,bq], acc [B,H,G,bq,D]); s: [B,H,G,bq,bk].
    """
    m, l, acc = carry
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32))
    acc_new = acc * corr[..., None] + pv
    return m_new, l_new, acc_new


def _pad_to(x, axis, mult):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      block_q: int = 512, block_kv: int = 512,
                      block_skip: bool = True, q_offset: int = 0):
    """Memory-efficient attention; never materializes [Sq,Sk].

    causal + block_skip: triangular iteration space — exactly the lower-
    triangular blocks are computed (FLOP-exact, no masked-block waste).
    window: only blocks intersecting the window are visited.
    """
    B, Sq, Hq, Dh = q.shape
    Sk, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    in_dtype = q.dtype

    block_q = min(block_q, max(16, Sq))
    block_kv = min(block_kv, max(16, Sk))
    q, _padq = _pad_to(q, 1, block_q)
    k, _padk = _pad_to(k, 1, block_kv)
    v, _ = _pad_to(v, 1, block_kv)
    Sqp, Skp = q.shape[1], k.shape[1]
    nQ, nK = Sqp // block_q, Skp // block_kv

    qg = q.reshape(B, nQ, block_q, Hk, G, Dh)
    kb = k.reshape(B, nK, block_kv, Hk, Dh)
    vb = v.reshape(B, nK, block_kv, Hk, Dh)

    def init_carry():
        m = jnp.full((B, Hk, G, block_q), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hk, G, block_q), jnp.float32)
        acc = jnp.zeros((B, Hk, G, block_q, Dh), jnp.float32)
        return m, l, acc

    def finalize(m, l, acc):
        l = jnp.where(l == 0.0, 1.0, l)
        out = acc / l[..., None]                      # [B,H,G,bq,D]
        return out.transpose(0, 3, 1, 2, 4)           # [B,bq,Hk,G,D]

    if causal and block_skip and window == 0 and q_offset == 0 and nQ == nK:
        # Triangular iteration: flat scan over (i,j) with j<=i.
        pairs = [(i, j) for i in range(nQ) for j in range(i + 1)]
        ij = jnp.array(pairs, jnp.int32)              # [T,2]
        is_row_start = jnp.array([j == 0 for _, j in pairs], bool)
        is_row_end = jnp.array([j == i for i, j in pairs], bool)

        out_buf = jnp.zeros((nQ, B, block_q, Hk, G, Dh), jnp.float32)

        def body(carry, inp):
            m, l, acc, out = carry
            (i, j), row_start, row_end = inp
            m = jnp.where(row_start, NEG_INF, m)
            l = jnp.where(row_start, 0.0, l)
            acc = jnp.where(row_start, 0.0, acc)
            qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
            kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
            qpos = i * block_q + jnp.arange(block_q)
            kpos = j * block_kv + jnp.arange(block_kv)
            s = _block_scores(qi, kj, qpos, kpos, True, 0)
            m, l, acc = _online_update((m, l, acc), s, vj)
            fin = finalize(m, l, acc)
            out = jax.lax.cond(
                row_end,
                lambda o: jax.lax.dynamic_update_index_in_dim(o, fin, i, 0),
                lambda o: o, out)
            return (m, l, acc, out), None

        carry0 = (*init_carry(), out_buf)
        (m, l, acc, out_buf), _ = jax.lax.scan(
            body, carry0, (ij, is_row_start, is_row_end))
        out = out_buf.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, Hq, Dh)
        return out[:, :Sq].astype(in_dtype)

    # Generic path: scan over q blocks; inner scan over a kv-block range.
    w_blocks = (window + block_kv - 1) // block_kv + 1 if window else 0

    def q_block_body(_, i):
        qi = jax.lax.dynamic_index_in_dim(qg, i, 1, keepdims=False)
        qpos = q_offset + i * block_q + jnp.arange(block_q)

        if window:
            # visit blocks j in [jc - w_blocks + ... , jc]; jc = block of q end
            jc = (q_offset + (i + 1) * block_q - 1) // block_kv
            deltas = jnp.arange(w_blocks + 1)
            js = jnp.clip(jc - w_blocks + deltas, -1, nK - 1)
        else:
            js = jnp.arange(nK)

        def kv_body(carry, j):
            kj = jax.lax.dynamic_index_in_dim(kb, jnp.maximum(j, 0), 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, jnp.maximum(j, 0), 1, keepdims=False)
            kpos = jnp.where(j < 0, -1, j * block_kv + jnp.arange(block_kv))
            s = _block_scores(qi, kj, qpos, kpos, causal, window)
            return _online_update(carry, s, vj), None

        (m, l, acc), _ = jax.lax.scan(kv_body, init_carry(), js)
        return None, finalize(m, l, acc)

    _, outs = jax.lax.scan(q_block_body, None, jnp.arange(nQ))   # [nQ,B,bq,H,G,D]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sqp, Hq, Dh)
    return out[:, :Sq].astype(in_dtype)


# ---------------------------------------------------------------------------
# Decode attention (single new token against a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0):
    """q: [B,Hq,Dh]; caches: [B,Smax,Hkv,Dh]; cur_len: int [] or per-slot
    [B] (tokens valid per batch row — continuous batching).

    For sliding-window layers the cache is a ring buffer of size ``window``
    and every slot < min(cur_len, window) is valid.
    """
    B, Hq, Dh = q.shape
    Smax, Hk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Hk, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    s = s / jnp.sqrt(Dh).astype(jnp.float32)
    kpos = jnp.arange(Smax)
    cur = jnp.broadcast_to(jnp.asarray(cur_len), (B,))
    limit = jnp.minimum(cur, window) if window else cur
    valid = kpos[None, :] < limit[:, None]                 # [B,Smax]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, Dh).astype(q.dtype)


def update_kv_cache(k_cache, v_cache, k_new, v_new, pos, *, window: int = 0):
    """Insert k/v at ``pos`` ([B,1,Hkv,Dh] or [B,S,Hkv,Dh] prefill).

    ``pos`` may be a scalar (shared position) or [B] (per-slot positions —
    continuous batching; requires S == 1).
    """
    # never let the insert promote the cache (a f32 update would carry the
    # WHOLE cache in f32 through the layer scan — 2x HBM + convert traffic)
    k_new = k_new.astype(k_cache.dtype)
    v_new = v_new.astype(v_cache.dtype)
    pos = jnp.asarray(pos)
    if pos.ndim == 1:
        assert k_new.shape[1] == 1, "per-slot insert is decode-only"
        B = k_new.shape[0]
        idx = (pos % window) if window else pos
        k_cache = k_cache.at[jnp.arange(B), idx].set(k_new[:, 0])
        v_cache = v_cache.at[jnp.arange(B), idx].set(v_new[:, 0])
        return k_cache, v_cache
    if window:
        S = k_new.shape[1]
        idx = (pos + jnp.arange(S)) % window
        k_cache = k_cache.at[:, idx].set(k_new)
        v_cache = v_cache.at[:, idx].set(v_new)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new, pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new, pos, axis=1)
    return k_cache, v_cache
