"""Logical-axis -> mesh-axis resolution and activation sharding helpers.

Two built-in rule-sets:

* ``tp_dp``   — tensor parallel over ``model``; params replicated over ``data``
                (fine for <= ~10B configs).
* ``fsdp_tp`` — additionally shards the layer-stacked dim / embed dims over
                ``data`` (ZeRO-3 style); required for the 340B/314B configs.

The ``pod`` axis (multi-pod mesh) joins ``data`` for batch / FSDP sharding.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# logical axis -> mesh axes, per rule-set.  Entries may be a tuple of mesh
# axes (sharded over their product) or None (replicated).
RULESETS: dict[str, dict[str, Any]] = {
    "tp_dp": {
        "vocab": "model",
        "embed": None,
        "embed2": None,
        "ff": "model",
        "expert_ff": None,
        "experts": "model",
        "q_heads": "model",
        "kv_heads": "model",
        "head": None,
        "layers": None,
        "rnn": "model",
        "rnn_heads": None,
    },
    "fsdp_tp": {
        "vocab": "model",
        "embed": "data",          # FSDP: shard the big embed dim over data
        "embed2": None,
        "ff": "model",
        "expert_ff": "model",
        "experts": None,          # overridden to "model" when moe.parallelism == "ep"
        "q_heads": "model",
        "kv_heads": "model",
        "head": None,
        "layers": None,
        "rnn": "model",
        "rnn_heads": None,
    },
}


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that make up the data-parallel dimension (pod folds in)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def resolve_rules(ruleset: str, mesh: Mesh, ep: bool = False) -> dict[str, Any]:
    rules = dict(RULESETS[ruleset])
    if ep:
        rules["experts"] = "model"
        rules["expert_ff"] = None
    if ruleset == "fsdp_tp" and rules.get("embed") == "data":
        rules["embed"] = data_axes(mesh) or None
    return rules


def spec_for_axes(axes: tuple, rules: dict[str, Any],
                  shape: Optional[tuple] = None,
                  mesh: Optional[Mesh] = None) -> P:
    """Resolve logical axes to a PartitionSpec.  When ``shape`` and ``mesh``
    are given, mesh axes that do not divide the dimension are dropped
    (e.g. 8 GQA kv heads on a 16-way model axis replicate — the standard
    KV-replication fallback)."""
    parts = []
    used: set[str] = set()
    for i, ax in enumerate(axes):
        m = rules.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
            continue
        ms = tuple(a for a in ((m,) if isinstance(m, str) else tuple(m))
                   if a not in used)
        if shape is not None and mesh is not None and i < len(shape):
            keep, prod = [], 1
            for a in ms:
                size = mesh.shape[a]
                if shape[i] % (prod * size) == 0:
                    keep.append(a)
                    prod *= size
            ms = tuple(keep)
        used.update(ms)
        if not ms:
            parts.append(None)
        else:
            parts.append(ms if len(ms) != 1 else ms[0])
    return P(*parts)


def param_shardings(param_axes: PyTree, mesh: Mesh, ruleset: str = "tp_dp",
                    ep: bool = False, shapes: Optional[PyTree] = None
                    ) -> PyTree:
    rules = resolve_rules(ruleset, mesh, ep=ep)
    is_axes = lambda x: isinstance(x, tuple)
    if shapes is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, spec_for_axes(axes, rules)),
            param_axes, is_leaf=is_axes)
    ax_leaves, treedef = jax.tree_util.tree_flatten(param_axes,
                                                    is_leaf=is_axes)
    shp_leaves = jax.tree_util.tree_flatten(shapes)[0]
    out = [NamedSharding(mesh, spec_for_axes(a, rules, tuple(s.shape), mesh))
           for a, s in zip(ax_leaves, shp_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------

_ACT_SPECS = {
    # [batch, seq, embed]
    "act_btd": lambda d: P(d, None, None),
    # [batch, seq, heads, head_dim]
    "act_bthd": lambda d: P(d, None, "model", None),
    # [batch, heads, ...]   (decode: no seq dim)
    "act_bhd": lambda d: P(d, "model", None),
    # sequence-sharded long-context activations [batch, seq, embed]
    "act_seq": lambda d: P(None, d, None),
    # logits chunk [batch, chunk, vocab]
    "act_btv": lambda d: P(d, None, "model"),
}


def shard_act(x, kind: str, mesh: Optional[Mesh] = None):
    """Apply a named activation sharding constraint (no-op without a mesh).
    Mesh axes that do not divide the corresponding dimension are dropped
    (e.g. 40 attention heads on a 16-way model axis)."""
    mesh = mesh if mesh is not None else _current_mesh()
    if mesh is None or mesh.empty:
        return x
    d = data_axes(mesh)
    d = d if len(d) > 1 else (d[0] if d else None)
    spec = _ACT_SPECS[kind](d)
    parts = []
    for i, p in enumerate(spec):
        if p is None or i >= x.ndim:
            parts.append(None)
            continue
        axes = (p,) if isinstance(p, str) else tuple(p)
        keep, prod = [], 1
        for a in axes:
            if x.shape[i] % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        parts.append(None if not keep
                     else (keep[0] if len(keep) == 1 else tuple(keep)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def shard_dims(x, dims: tuple, mesh: Optional[Mesh] = None):
    """Generic per-dim constraint: 'dp' -> data axes, 'tp' -> model, None ->
    replicated.  Non-divisible dims silently replicate."""
    mesh = mesh if mesh is not None else _current_mesh()
    if mesh is None or mesh.empty:
        return x
    d = data_axes(mesh)
    parts: list = []
    for i, tag in enumerate(dims[:x.ndim]):
        if tag == "dp":
            axes = d
        elif tag == "tp":
            axes = ("model",)
        else:
            parts.append(None)
            continue
        keep, prod = [], 1
        for a in axes:
            if x.shape[i] % (prod * mesh.shape[a]) == 0:
                keep.append(a)
                prod *= mesh.shape[a]
        parts.append(None if not keep
                     else (keep[0] if len(keep) == 1 else tuple(keep)))
    parts += [None] * (x.ndim - len(parts))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


_MESH_STACK: list[Mesh] = []


class use_mesh:
    """Context manager installing a mesh for shard_act constraints."""

    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        _MESH_STACK.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _MESH_STACK.pop()
        return False


def _current_mesh() -> Optional[Mesh]:
    return _MESH_STACK[-1] if _MESH_STACK else None
