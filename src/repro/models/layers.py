"""Core layers: norms, MLPs, rotary embeddings, token embedding / LM head."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dot, fan_in_init, normal_init, ones_init, zeros_init


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(keys: KeyGen, d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"scale": ones_init(keys(), (d,), dtype)}
    if kind == "layernorm":
        return {"scale": ones_init(keys(), (d,), dtype), "bias": zeros_init(keys(), (d,), dtype)}
    if kind == "nonparam_ln":      # OLMo: LN without learnable params
        return {}
    raise ValueError(kind)


def apply_norm(params, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP  (swiglu / sq_relu / gelu)
# ---------------------------------------------------------------------------

def init_mlp(keys: KeyGen, d: int, f: int, activation: str, dtype):
    p = {"wi": normal_init(keys(), (d, f), dtype), "wo": fan_in_init(keys(), (f, d), dtype)}
    if activation in ("swiglu", "geglu"):
        p["wg"] = normal_init(keys(), (d, f), dtype)
    return p


def apply_mlp(params, x, activation: str):
    h = dot(x, params["wi"])
    if activation == "swiglu":
        g = dot(x, params["wg"])
        h = jax.nn.silu(g) * h
    elif activation == "geglu":             # Gemma family: gated GELU
        g = dot(x, params["wg"])
        h = jax.nn.gelu(g) * h
    elif activation == "sq_relu":           # Nemotron-4: squared ReLU
        h = jnp.square(jax.nn.relu(h))
    elif activation == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(activation)
    return dot(h, params["wo"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)              # [head_dim/2]


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs     # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(keys: KeyGen, vocab: int, d: int, dtype, with_pos: int = 0):
    p = {"tok": normal_init(keys(), (vocab, d), dtype)}
    if with_pos:
        p["pos"] = normal_init(keys(), (with_pos, d), dtype)
    return p


def embed_tokens(params, tokens):
    return jnp.take(params["tok"], tokens, axis=0)


def init_head(keys: KeyGen, d: int, vocab: int, dtype):
    return {"w": normal_init(keys(), (d, vocab), dtype)}


def apply_head(params, x, embed_params=None, softcap: float = 0.0):
    """LM head; uses tied embedding transpose when ``params`` is None."""
    from repro.models.common import _safe_dot
    w = embed_params["tok"].T if params is None else params["w"]
    if _safe_dot() and x.dtype == jnp.bfloat16:
        logits = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    else:
        logits = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits
