"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Structure per recurrent block:
    x -> w_in -> u -----conv1d(w=4, causal)----> RG-LRU ---⊙--- w_out -> out
    x -> w_gate_in -> gelu gate -----------------------------^

RG-LRU:  r_t = σ(u_t W_a),  i_t = σ(u_t W_i)
         log a_t = -c * softplus(Λ) * r_t          (c = 8)
         h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t)

Train/prefill uses ``jax.lax.associative_scan`` over time (O(log S) depth —
this is what makes ``long_500k`` feasible); decode is a single recurrent step
with O(1) state: (h, conv ring buffer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dot, fan_in_init, normal_init, zeros_init

_C = 8.0


def init_rglru_block(keys: KeyGen, d: int, width: int, conv_width: int, dtype):
    return {
        "w_in": normal_init(keys(), (d, width), dtype),
        "w_gate_in": normal_init(keys(), (d, width), dtype),
        "conv_w": normal_init(keys(), (conv_width, width), dtype, scale=0.1),
        "conv_b": zeros_init(keys(), (width,), dtype),
        "w_a": normal_init(keys(), (width, width), dtype, scale=0.02),
        "w_i": normal_init(keys(), (width, width), dtype, scale=0.02),
        "lam": normal_init(keys(), (width,), jnp.float32, scale=0.5),
        "w_out": fan_in_init(keys(), (width, d), dtype),
    }


def _causal_conv(u, conv_w, conv_b):
    """u: [B,S,W]; depthwise causal conv along S."""
    cw = conv_w.shape[0]
    pad = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * conv_w[i] for i in range(cw))
    return out + conv_b


def _gates(params, u):
    r = jax.nn.sigmoid(dot(u, params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(dot(u, params["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b_scale = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, b_scale * i * u.astype(jnp.float32)


def apply_rglru_block(params, x, *, h0=None, conv_state=None, return_state=False):
    """x: [B,S,D] -> [B,S,D].  h0/conv_state: decode-style initial state."""
    u = dot(x, params["w_in"])
    gate = jax.nn.gelu(dot(x, params["w_gate_in"]))
    if conv_state is not None:
        cw = params["conv_w"].shape[0]
        hist = jnp.concatenate([conv_state, u], axis=1)           # [B, cw-1+S, W]
        uc = _causal_conv(hist, params["conv_w"], params["conv_b"])[:, cw - 1:]
        new_conv_state = hist[:, -(cw - 1):]
    else:
        uc = _causal_conv(u, params["conv_w"], params["conv_b"])
        new_conv_state = None

    a, b = _gates(params, uc)
    if h0 is not None:
        # seed the scan with the carried state via a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)
    aa, hh = jax.lax.associative_scan(
        lambda l, r: (r[0] * l[0], r[0] * l[1] + r[1]), (a, b), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    h = hh.astype(x.dtype)
    out = dot(gate * h, params["w_out"])
    if return_state:
        return out, (h[:, -1], new_conv_state)
    return out


def init_rglru_state(batch: int, width: int, conv_width: int, dtype):
    return (jnp.zeros((batch, width), dtype),
            jnp.zeros((batch, conv_width - 1, width), dtype))


def decode_rglru_block(params, x, state):
    """x: [B,1,D]; state: (h [B,W], conv_state [B,cw-1,W]) -> (out [B,1,D], state)."""
    h_prev, conv_state = state
    out, (h, new_conv) = apply_rglru_block(
        params, x, h0=h_prev, conv_state=conv_state, return_state=True)
    return out, (h, new_conv)
