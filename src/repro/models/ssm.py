"""xLSTM blocks (arXiv:2405.04517): chunk-parallel mLSTM + sequential sLSTM.

mLSTM (matrix memory, exponentially gated):
    C_t = f_t C_{t-1} + i_t v_t k_t^T        n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
computed here in the *stabilized chunkwise-parallel* form (log-space gate
cumsums, per-row running max M_t, state carried as (Ĉ, n̂, m) with
Ĉ = C e^{-m}).  Within-chunk work is attention-like (quadratic in the chunk),
across chunks a lax.scan — this is what lets prefill_32k lower without a
32k-step while loop.

sLSTM (scalar memory, recurrent head-wise connections) is a true nonlinear
recurrence and is executed as a per-timestep lax.scan (not parallelizable —
inherent to the architecture; noted in DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dot, einsum, fan_in_init, normal_init, zeros_init
from repro.models.layers import apply_mlp, init_mlp

MLSTM_EXPANSION = 2.0
SLSTM_FF_EXPANSION = 8.0 / 3.0
NEG_INF = -1e30


# ===========================================================================
# mLSTM
# ===========================================================================

def init_mlstm_block(keys: KeyGen, d: int, n_heads: int, conv_width: int, dtype):
    di = int(MLSTM_EXPANSION * d)
    hd = di // n_heads
    return {
        "w_up": normal_init(keys(), (d, 2 * di), dtype),
        "conv_w": normal_init(keys(), (conv_width, di), dtype, scale=0.1),
        "w_q": normal_init(keys(), (di, n_heads, hd), dtype),
        "w_k": normal_init(keys(), (di, n_heads, hd), dtype),
        "w_v": normal_init(keys(), (di, n_heads, hd), dtype),
        "w_ig": normal_init(keys(), (di, n_heads), dtype, scale=0.01),
        "b_ig": zeros_init(keys(), (n_heads,), jnp.float32),
        "w_fg": normal_init(keys(), (di, n_heads), dtype, scale=0.01),
        "b_fg": 3.0 * jnp.ones((n_heads,), jnp.float32),
        "w_down": fan_in_init(keys(), (di, d), dtype),
    }


class MLstmState(NamedTuple):
    C: jax.Array      # [B,H,Dk,Dv]  scaled by e^{-m}
    n: jax.Array      # [B,H,Dk]     scaled by e^{-m}
    m: jax.Array      # [B,H]


def init_mlstm_state(batch: int, n_heads: int, hd: int) -> MLstmState:
    return MLstmState(
        C=jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        n=jnp.zeros((batch, n_heads, hd), jnp.float32),
        m=jnp.full((batch, n_heads), NEG_INF, jnp.float32),
    )


def _mlstm_qkv_gates(params, x):
    """x: [B,S,D] -> q,k,v [B,S,H,hd], i/f gate logits [B,S,H], o-gate input."""
    u = dot(x, params["w_up"])
    c_in, o_in = jnp.split(u, 2, axis=-1)
    cw = params["conv_w"].shape[0]
    pad = jnp.pad(c_in, ((0, 0), (cw - 1, 0), (0, 0)))
    c_conv = sum(pad[:, i:i + x.shape[1]] * params["conv_w"][i] for i in range(cw))
    c_conv = jax.nn.silu(c_conv)
    q = einsum("btd,dhk->bthk", c_conv, params["w_q"])
    k = einsum("btd,dhk->bthk", c_conv, params["w_k"]) / jnp.sqrt(q.shape[-1]).astype(x.dtype)
    v = einsum("btd,dhk->bthk", c_in, params["w_v"])
    ig = einsum("btd,dh->bth", c_in, params["w_ig"], out_dtype=jnp.float32) + params["b_ig"]
    fg = einsum("btd,dh->bth", c_in, params["w_fg"], out_dtype=jnp.float32) + params["b_fg"]
    return q, k, v, ig, fg, o_in


def _mlstm_chunk(state: MLstmState, qkvif):
    """Process one chunk of length L.  All in f32."""
    q, k, v, ig, fg = qkvif                  # q/k/v: [B,L,H,hd]; ig/fg: [B,L,H]
    B, L, H, hd = q.shape
    q, k, v = (t.astype(jnp.float32).transpose(0, 2, 1, 3) for t in (q, k, v))
    ig = ig.transpose(0, 2, 1)               # [B,H,L]
    logf = jax.nn.log_sigmoid(fg).transpose(0, 2, 1)
    b = jnp.cumsum(logf, axis=-1)            # [B,H,L]  cumulative log forget
    b_total = b[..., -1]

    # scores D[t,s] = b_t - b_s + ig_s   (s <= t)
    Dm = b[..., :, None] - b[..., None, :] + ig[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(mask, Dm, NEG_INF)
    m_intra = Dm.max(axis=-1)                              # [B,H,L]
    m_inter = b + state.m[..., None]                       # C_0 contribution scale
    M = jnp.maximum(m_intra, m_inter)                      # [B,H,L]
    M = jnp.maximum(M, -NEG_INF * 0 - 50.0 + 0 * M)        # floor to avoid inf underflow
    P = jnp.exp(Dm - M[..., None])                         # [B,H,L,L]

    scores = jnp.einsum("bhtd,bhsd->bhts", q, k)           # k pre-scaled by 1/sqrt(hd)
    W = P * scores
    num_intra = jnp.einsum("bhts,bhsd->bhtd", W, v)
    den_intra = jnp.einsum("bhts,bhsd->bht", W, k)

    inter_scale = jnp.exp(b + state.m[..., None] - M)      # [B,H,L]
    num_inter = jnp.einsum("bhtd,bhdk->bhtk", q, state.C) * inter_scale[..., None]
    den_inter = jnp.einsum("bhtd,bhd->bht", q, state.n) * inter_scale

    num = num_intra + num_inter
    den = den_intra + den_inter
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-M))[..., None]

    # ---- state update to end of chunk ----
    decay = b_total[..., None] - b + ig                    # [B,H,L]
    m_new = jnp.maximum(b_total + state.m, decay.max(axis=-1))
    carry_scale = jnp.exp(b_total + state.m - m_new)
    upd = jnp.exp(decay - m_new[..., None])                # [B,H,L]
    C_new = state.C * carry_scale[..., None, None] + jnp.einsum(
        "bhs,bhsd,bhse->bhde", upd, k, v)
    n_new = state.n * carry_scale[..., None] + jnp.einsum("bhs,bhsd->bhd", upd, k)
    return MLstmState(C_new, n_new, m_new), h.transpose(0, 2, 1, 3)   # [B,L,H,hd]


def apply_mlstm_block(params, x, *, chunk: int = 256, state: MLstmState = None,
                      return_state: bool = False):
    """x: [B,S,D] -> [B,S,D] (chunkwise-parallel mLSTM).

    With ``return_state`` returns (out, (MLstmState, conv_tail)) where
    conv_tail is the last ``conv_width-1`` pre-conv activations (decode carry).
    """
    B, S, D = x.shape
    q, k, v, ig, fg, o_in = _mlstm_qkv_gates(params, x)
    H, hd = q.shape[2], q.shape[3]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, padw) for t in (q, k, v))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=NEG_INF)
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)))
    nC = q.shape[1] // chunk

    def split(t):
        return t.reshape(B, nC, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = tuple(split(t) for t in (q, k, v, ig, fg))
    st0 = state if state is not None else init_mlstm_state(B, H, hd)
    st, hs = jax.lax.scan(_mlstm_chunk, st0, xs)           # hs: [nC,B,chunk,H,hd]
    h = hs.swapaxes(0, 1).reshape(B, nC * chunk, H * hd)[:, :S].astype(x.dtype)
    out = dot(h * jax.nn.silu(o_in), params["w_down"])
    if return_state:
        cw = params["conv_w"].shape[0]
        c_in = dot(x, params["w_up"])[..., : params["w_q"].shape[0]]
        tail = jnp.pad(c_in, ((0, 0), (cw - 1, 0), (0, 0)))[:, -(cw - 1):]
        return out, (st, tail)
    return out


def decode_mlstm_block(params, x, state: MLstmState, conv_state):
    """Single-token recurrent step.  x: [B,1,D]."""
    cw = params["conv_w"].shape[0]
    u = dot(x, params["w_up"])
    c_in, o_in = jnp.split(u, 2, axis=-1)
    hist = jnp.concatenate([conv_state, c_in], axis=1)     # [B,cw,Di]
    c_conv = jax.nn.silu(jnp.einsum("btd,td->bd", hist, params["conv_w"]))[:, None]
    q = einsum("btd,dhk->bthk", c_conv, params["w_q"])[:, 0].astype(jnp.float32)
    k = (einsum("btd,dhk->bthk", c_conv, params["w_k"])[:, 0] /
         jnp.sqrt(q.shape[-1])).astype(jnp.float32)
    v = einsum("btd,dhk->bthk", c_in, params["w_v"])[:, 0].astype(jnp.float32)
    ig = (einsum("btd,dh->bth", c_in, params["w_ig"], out_dtype=jnp.float32)[:, 0]
          + params["b_ig"])
    fg = (einsum("btd,dh->bth", c_in, params["w_fg"], out_dtype=jnp.float32)[:, 0]
          + params["b_fg"])
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + state.m, ig)
    f_s = jnp.exp(logf + state.m - m_new)
    i_s = jnp.exp(ig - m_new)
    C = state.C * f_s[..., None, None] + i_s[..., None, None] * k[..., :, None] * v[..., None, :]
    n = state.n * f_s[..., None] + i_s[..., None] * k
    num = jnp.einsum("bhd,bhdk->bhk", q, C)
    den = jnp.einsum("bhd,bhd->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    B = x.shape[0]
    h = h.reshape(B, 1, -1).astype(x.dtype)
    out = dot(h * jax.nn.silu(o_in), params["w_down"])
    return out, MLstmState(C, n, m_new), hist[:, 1:]


def init_mlstm_conv_state(batch: int, d: int, conv_width: int, dtype):
    return jnp.zeros((batch, conv_width - 1, int(MLSTM_EXPANSION * d)), dtype)


# ===========================================================================
# sLSTM
# ===========================================================================

def init_slstm_block(keys: KeyGen, d: int, n_heads: int, dtype):
    hd = d // n_heads
    p = {}
    for g in ("i", "f", "z", "o"):
        p[f"w_{g}"] = normal_init(keys(), (d, n_heads, hd), dtype)
        p[f"r_{g}"] = normal_init(keys(), (n_heads, hd, hd), dtype, scale=0.02)
        p[f"b_{g}"] = (2.0 if g == "f" else 0.0) * jnp.ones((n_heads, hd), jnp.float32)
    f = int(SLSTM_FF_EXPANSION * d) // 64 * 64 or 64
    p["ffn_wi"] = normal_init(keys(), (d, f), dtype)
    p["ffn_wg"] = normal_init(keys(), (d, f), dtype)
    p["ffn_wo"] = fan_in_init(keys(), (f, d), dtype)
    return p


class SLstmState(NamedTuple):
    c: jax.Array    # [B,H,hd]
    n: jax.Array
    h: jax.Array
    m: jax.Array


def init_slstm_state(batch: int, n_heads: int, hd: int) -> SLstmState:
    z = jnp.zeros((batch, n_heads, hd), jnp.float32)
    return SLstmState(z, z, z, z + NEG_INF)


def _slstm_step(params, state: SLstmState, wx):
    """wx: dict of pre-computed input contributions [B,H,hd] per gate."""
    rec = {g: jnp.einsum("bhd,hdk->bhk", state.h, params[f"r_{g}"].astype(jnp.float32))
           for g in ("i", "f", "z", "o")}
    il = wx["i"] + rec["i"] + params["b_i"]
    fl = wx["f"] + rec["f"] + params["b_f"]
    zl = jnp.tanh(wx["z"] + rec["z"] + params["b_z"])
    ol = jax.nn.sigmoid(wx["o"] + rec["o"] + params["b_o"])
    logf = jax.nn.log_sigmoid(fl)
    m_new = jnp.maximum(logf + state.m, il)
    i_s = jnp.exp(il - m_new)
    f_s = jnp.exp(logf + state.m - m_new)
    c = f_s * state.c + i_s * zl
    n = jnp.maximum(f_s * state.n + i_s, 1e-6)
    h = ol * c / n
    return SLstmState(c, n, h, m_new), h


def apply_slstm_block(params, x, *, state: SLstmState = None, return_state: bool = False):
    """x: [B,S,D] -> [B,S,D] (sequential scan; inherent to sLSTM)."""
    B, S, D = x.shape
    H, hd = params["w_i"].shape[1], params["w_i"].shape[2]
    wx = {g: einsum("btd,dhk->bthk", x, params[f"w_{g}"], out_dtype=jnp.float32)
          for g in ("i", "f", "z", "o")}
    xs = jax.tree.map(lambda t: t.swapaxes(0, 1), wx)       # [S,B,H,hd]
    st0 = state if state is not None else init_slstm_state(B, H, hd)
    st, hs = jax.lax.scan(lambda s, w: _slstm_step(params, s, w), st0, xs)
    h = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    out = h + apply_mlp({"wi": params["ffn_wi"], "wg": params["ffn_wg"],
                         "wo": params["ffn_wo"]}, h, "swiglu")
    if return_state:
        return out, st
    return out


def decode_slstm_block(params, x, state: SLstmState):
    out, st = apply_slstm_block(params, x, state=state, return_state=True)
    return out, st
