"""Dev sanity: run every system under both engines, demand bit-for-bit
equality.  The committed parity suite is tests/test_engine_vec.py; this
script is the fast manual loop (python scripts/parity_check.py)."""
import sys

sys.path.insert(0, "src")

from repro.configs.registry import get_config
from repro.core import types as T
from repro.core.lithos import evaluate, SYSTEMS
from repro.core.scheduler import LithOSConfig
from repro.core.types import DeviceSpec, Priority
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")


def hp_app(rps=20.0, name="hp"):
    return AppSpec(name, OLMO, "fwd_infer", priority=Priority.HIGH,
                   rps=rps, prompt_mix=((128, 1.0),), batch=4, fusion=8)


def be_train(name="be"):
    return AppSpec(name, LLAMA, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=2048, fusion=8)


def cont_app(name="cont", rps=40.0):
    return AppSpec(name, OLMO, "llm_continuous", priority=Priority.HIGH,
                   rps=rps, max_batch=4, decode_tokens=8, fusion=8,
                   prompt_mix=((256, 0.7), (1024, 0.3)), seed=5)


def rec_sig(res):
    return [(r.task.kid, r.task.queue_id, r.task.ordinal, r.t_submit,
             r.t_start, r.t_end, r.slices, r.freq) for r in res.records]


def run(system, engine, horizon, cfg=None, apps=None):
    T.reset_kernel_ids()
    return evaluate(system, DEV, apps or [hp_app(), be_train()],
                    horizon=horizon, seed=0, engine=engine,
                    lithos_config=cfg)


def main():
    horizon = float(sys.argv[1]) if len(sys.argv) > 1 else 2.0
    configs = {s: (None, None) for s in SYSTEMS}
    configs["lithos-full"] = (LithOSConfig(rightsize=True, dvfs=True), None)
    # continuous-batching serving: dynamic per-iteration batch composition
    llm_apps = [cont_app(), be_train()]
    configs["lithos-llm"] = (None, llm_apps)
    configs["mps-llm"] = (None, llm_apps)
    configs["lithos-full-llm"] = (LithOSConfig(rightsize=True, dvfs=True),
                                  llm_apps)
    failures = 0
    for label, (cfg, apps) in configs.items():
        system = label.split("-")[0]
        a = run(system, "ref", horizon, cfg, apps)
        b = run(system, "vec", horizon, cfg, apps)
        ok = True
        msgs = []
        if rec_sig(a) != rec_sig(b):
            sa, sb = rec_sig(a), rec_sig(b)
            ok = False
            n = next((i for i, (x, y) in enumerate(zip(sa, sb)) if x != y),
                     min(len(sa), len(sb)))
            msgs.append(f"records differ at #{n}/{len(sa)}v{len(sb)}: "
                        f"{sa[n] if n < len(sa) else '<end>'} vs "
                        f"{sb[n] if n < len(sb) else '<end>'}")
        if a.energy != b.energy:
            ok = False
            msgs.append(f"energy {a.energy!r} vs {b.energy!r}")
        if a.busy_slice_seconds != b.busy_slice_seconds:
            ok = False
            msgs.append(f"busy {a.busy_slice_seconds!r} vs "
                        f"{b.busy_slice_seconds!r}")
        for ca, cb in zip(a.clients, b.clients):
            if ca.slice_seconds != cb.slice_seconds:
                ok = False
                msgs.append(f"{ca.name} slice_seconds {ca.slice_seconds!r} "
                            f"vs {cb.slice_seconds!r}")
            if ca.latencies != cb.latencies:
                ok = False
                msgs.append(f"{ca.name} latencies differ "
                            f"({len(ca.latencies)} vs {len(cb.latencies)})")
            if ca.req_latencies != cb.req_latencies:
                ok = False
                msgs.append(f"{ca.name} req_latencies differ "
                            f"({len(ca.req_latencies or [])} vs "
                            f"{len(cb.req_latencies or [])})")
        print(f"{'OK ' if ok else 'FAIL'} {label:14s} "
              f"records={len(a.records)}")
        for m in msgs:
            print(f"     {m}")
        failures += not ok
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
