#!/usr/bin/env bash
# Control-plane smoke: exercise the daemon's whole online story end to end
# against a throwaway state dir — submit two jobs, watch them run, cancel
# one, kill -9 the daemon mid-flight, restart it and verify the interrupted
# job recovers and finishes.  Run under `timeout` from CI (the script
# itself polls with bounded loops so a wedged daemon fails, not hangs).
set -euo pipefail

DIR=$(mktemp -d /tmp/ctl-smoke.XXXXXX)
trap 'kill -9 $DPID 2>/dev/null || true; rm -rf "$DIR"' EXIT
CTL="python -m repro.ctl"
export PYTHONPATH=${PYTHONPATH:-src}

state_of() { $CTL status --state-dir "$DIR" --json \
  | python -c "import json,sys; d=json.load(sys.stdin); \
print(next((j['state'] for j in d['jobs'] if j['job_id']=='$1'), 'absent'))"; }

wait_state() {     # job_id  want  tries
  for _ in $(seq "${3:-150}"); do
    s=$(state_of "$1")
    [ "$s" = "$2" ] && return 0
    sleep 0.2
  done
  echo "FAIL: $1 stuck in '$s' (wanted $2)"; $CTL status --state-dir "$DIR"
  return 1
}

echo "== submit two jobs, start the daemon =="
JOB_A=$($CTL submit --state-dir "$DIR" --kind serve --rps 25 --duration 6 \
        --priority hp --quota 6 --name svc-a)
JOB_B=$($CTL submit --state-dir "$DIR" --kind train --duration 40 --name trn-b)
$CTL daemon --state-dir "$DIR" --devices 2 & DPID=$!

wait_state "$JOB_A" running
wait_state "$JOB_B" running
$CTL status --state-dir "$DIR"

echo "== cancel one job while it runs =="
$CTL cancel --state-dir "$DIR" "$JOB_B"
wait_state "$JOB_B" cancelled

echo "== kill -9 the daemon mid-flight =="
kill -9 "$DPID"; wait "$DPID" 2>/dev/null || true
[ "$(state_of "$JOB_A")" = running ] || { echo "FAIL: journal lost $JOB_A"; exit 1; }

echo "== restart: recovery must resume and finish the interrupted job =="
$CTL daemon --state-dir "$DIR" --devices 2 --exit-when-idle --max-wall 240
wait_state "$JOB_A" done 5
$CTL status --state-dir "$DIR"

RECOVERIES=$($CTL status --state-dir "$DIR" --json \
  | python -c "import json,sys; d=json.load(sys.stdin); \
print(next(j['recoveries'] for j in d['jobs'] if j['job_id']=='$JOB_A'))")
[ "$RECOVERIES" = 1 ] || { echo "FAIL: expected 1 recovery, got $RECOVERIES"; exit 1; }
echo "ctl smoke OK (job $JOB_A recovered once, cancel honored, no loss)"
