"""Quickstart: the three layers of LithOS-TPU in ~60 seconds on CPU.

1. Train a reduced LM on the synthetic pipeline (execution plane).
2. Serve it with continuous batching (serving substrate).
3. Stack an inference service with a best-effort trainer under LithOS vs
   MPS and compare tail latencies (the paper's control plane).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.registry import get_config
from repro.core.lithos import evaluate
from repro.core.types import DeviceSpec, Priority
from repro.core.workloads import AppSpec
from repro.launch.train import train
from repro.serve.engine import ServeConfig, SlotServer
from repro.train.step import TrainConfig


def main():
    # -- 1. train ------------------------------------------------------------
    cfg = get_config("olmo-1b").reduced()
    print("== training reduced olmo-1b on the synthetic corpus ==")
    state, losses = train(cfg, steps=20, batch=8, seq=64,
                          tc=TrainConfig(total_steps=20, warmup_steps=2),
                          log_every=5)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}\n")

    # -- 2. serve ------------------------------------------------------------
    print("== serving it with continuous batching ==")
    srv = SlotServer(cfg, params=state.params,
                     serve_cfg=ServeConfig(max_slots=3, max_len=64,
                                           max_new_tokens=8))
    rng = np.random.default_rng(0)
    for _ in range(6):
        srv.submit(rng.integers(2, cfg.vocab_size, 12).astype(np.int32))
    done = srv.run_until_drained()
    print(f"served {len(done)} requests; sample output tokens: "
          f"{done[0].output}\n")

    # -- 3. LithOS multi-tenancy ----------------------------------------------
    print("== stacking inference + training: LithOS vs MPS ==")
    dev = DeviceSpec.a100_like()
    apps = [
        AppSpec("inference", get_config("olmo-1b"), "fwd_infer",
                priority=Priority.HIGH, rps=20.0, batch=8,
                prompt_mix=((128, 1.0),), fusion=8),
        AppSpec("training", get_config("olmo-1b"), "train",
                priority=Priority.BEST_EFFORT, train_batch=8,
                train_seq=1024, fusion=8),
    ]
    for system in ("lithos", "mps"):
        res = evaluate(system, dev, apps, horizon=5.0, seed=0)
        inf, tr = res.client("inference"), res.client("training")
        print(f"  {system:8s}  inference p99 = {inf.p99*1e3:7.1f} ms   "
              f"training steps = {tr.n_completed}   util = "
              f"{res.utilization:.2f}")
    print("\nLithOS keeps inference tails flat while the trainer consumes "
          "idle capacity — the paper's core result.")


if __name__ == "__main__":
    main()
