"""Multi-tenant serving: the paper's inference-stacking experiment in
miniature — two HP services with SLOs plus a best-effort tenant, compared
across all nine scheduling systems.

Run:  PYTHONPATH=src python examples/multitenant_serving.py
"""
from dataclasses import replace

from repro.configs.registry import get_config
from repro.core.lithos import SYSTEMS, evaluate, run_alone
from repro.core.types import DeviceSpec, Priority
from repro.core.workloads import AppSpec, mean_demand


def main():
    dev = DeviceSpec.a100_like()
    hpa = AppSpec("hpA", get_config("olmo-1b"), "fwd_infer",
                  priority=Priority.HIGH, quota_slices=40, batch=8,
                  prompt_mix=((128, 1.0),), fusion=8)
    hpb = AppSpec("hpB", get_config("llama3-8b"), "llm_infer",
                  priority=Priority.HIGH, quota_slices=14,
                  prompt_mix=((2048, 1.0),), decode_tokens=6, fusion=8)
    # BE: sustained 8k-prompt pressure, TRT-LLM-style fused prefill kernels
    be = AppSpec("be", get_config("qwen2-moe-a2.7b"), "llm_infer",
                 priority=Priority.BEST_EFFORT, rps=0.0,
                 prompt_mix=((8192, 1.0),), decode_tokens=8, fusion=16)
    be2 = replace(be, name="be2", seed=97)
    # calibrate loads: HP A at 50% util, HP B at 15%
    da, db = mean_demand(hpa, dev), mean_demand(hpb, dev)
    hpa = replace(hpa, rps=0.5 / da, slo_latency=4 * da)
    hpb = replace(hpb, rps=0.15 / db, slo_latency=8 * db)

    ideal = run_alone(dev, hpa, horizon=8.0, seed=0).client("hpA").p99
    print(f"{'system':10s} {'hpA p99':>10s} {'vs ideal':>9s} "
          f"{'hpA SLO%':>9s} {'hpB done':>9s} {'BE done':>8s} {'util':>6s}")
    for system in SYSTEMS:
        res = evaluate(system, dev, [hpa, hpb, be, be2], horizon=8.0, seed=0)
        A, B, E = res.client("hpA"), res.client("hpB"), res.client("be")
        print(f"{system:10s} {A.p99*1e3:9.1f}ms {A.p99/ideal:8.1f}x "
              f"{A.slo_attainment(hpa.slo_latency)*100:8.1f}% "
              f"{B.n_completed:9d} {E.n_completed + res.client('be2').n_completed:8d} "
              f"{res.utilization:6.2f}")


if __name__ == "__main__":
    main()
