"""End-to-end driver: train an LM with the full production substrate —
sharded state, async checkpointing with restart, fault-tolerance
coordinator.

Default is a ~20M-param config sized for this CPU container; pass
``--hundred-m`` for the ~100M/200-step configuration (minutes per step on
1 CPU core; the intended target is a pod, where the same driver runs the
full configs).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60] [--hundred-m]
"""
import argparse
import dataclasses
import os
import shutil
import time

from repro.configs.registry import get_config
from repro.distributed.coordinator import Coordinator, CoordinatorConfig
from repro.launch.train import train
from repro.train.step import TrainConfig

CKPT = "/tmp/repro_train_lm_ckpt"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M params / 200 steps (pod-sized; slow on CPU)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.hundred_m:
        # ~100M params: olmo-1b family at width 768 / 12 layers
        cfg = dataclasses.replace(
            get_config("olmo-1b"), n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=12, d_ff=3072, vocab_size=32768)
        args.steps = max(args.steps, 200)
    else:
        cfg = dataclasses.replace(
            get_config("olmo-1b"), n_layers=6, d_model=384, n_heads=6,
            n_kv_heads=6, d_ff=1536, vocab_size=8192)
    n = cfg.param_count()
    print(f"model: olmo-family {n/1e6:.0f}M params")

    if not args.resume and os.path.isdir(CKPT):
        shutil.rmtree(CKPT)

    coord = Coordinator(1, CoordinatorConfig())
    tc = TrainConfig(remat="none", n_micro=1, lr=3e-4,
                     total_steps=args.steps,
                     warmup_steps=max(1, args.steps // 20))
    t0 = time.time()
    batch, seq = (8, 256) if args.hundred_m else (4, 128)
    state, losses = train(cfg, steps=args.steps, batch=batch, seq=seq, tc=tc,
                          ckpt_dir=CKPT, ckpt_every=20, log_every=10,
                          coordinator=coord)
    dt = time.time() - t0
    toks = args.steps * batch * seq
    print(f"\ndone: {args.steps} steps, loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}, {toks/dt:.0f} tok/s on CPU")
    print(f"checkpoints in {CKPT} (rerun with --resume to restart from "
          f"the latest)")
    print(f"coordinator events: {coord.events or 'none (healthy run)'}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
