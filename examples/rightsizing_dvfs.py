"""Right-sizing + DVFS walkthrough: LithOS learns per-kernel Amdahl curves
and frequency sensitivities online (calibration phase), then trades a
bounded latency slip for capacity and energy (measurement phase) — the
steady state a minutes-long production run reaches.

Run:  PYTHONPATH=src python examples/rightsizing_dvfs.py
"""
import dataclasses
from dataclasses import replace

from repro.configs.registry import get_config
from repro.core.lithos import make_policy, run_alone
from repro.core.scheduler import LithOSConfig
from repro.core.simulator import Simulator
from repro.core.types import DeviceSpec, Priority
from repro.core.workloads import AppSpec, mean_demand


def calibrated_run(dev, app, cfg, *, horizon, seed):
    """Calibrate (probes, f-exploration) then measure with learned state."""
    solo = replace(app, quota_slices=dev.n_slices)
    cal = make_policy("lithos", dev, [solo], lithos_config=cfg)
    Simulator(dev, [solo], cal, horizon=horizon, seed=seed + 1).run()
    meas = make_policy("lithos", dev, [solo],
                       lithos_config=dataclasses.replace(cfg,
                                                         probe_low=False))
    meas.predictor, meas.rightsizer, meas.governor = (
        cal.predictor, cal.rightsizer, cal.governor)
    meas.governor.current_f, meas.governor.last_switch = 1.0, -1e9
    sim = Simulator(dev, [solo], meas, horizon=horizon, seed=seed)
    res = sim.run()
    res.policy = meas
    return res


def main():
    dev = DeviceSpec.a100_like()
    app = AppSpec("svc", get_config("llama3-8b"), "llm_infer",
                  priority=Priority.HIGH, prompt_mix=((2048, 1.0),),
                  decode_tokens=8, fusion=8)
    d = mean_demand(app, dev)
    app = replace(app, rps=0.25 / d, slo_latency=5 * d)

    base = run_alone(dev, app, horizon=12.0, seed=0,
                     lithos_config=LithOSConfig(rightsize=False, dvfs=False,
                                                occupancy_filter=False))
    b99 = base.client("svc").p(99, 0.3)
    for slip in (1.05, 1.1, 1.25):
        res = calibrated_run(dev, app,
                             LithOSConfig(rightsize=True, dvfs=True,
                                          slip=slip),
                             horizon=12.0, seed=0)
        rs, gov = res.policy.rightsizer, res.policy.governor
        cap = 1 - res.client("svc").slice_seconds / max(
            base.client("svc").slice_seconds, 1e-9)
        en = 1 - (res.energy / max(res.client("svc").n_completed, 1)) / (
            base.energy / max(base.client("svc").n_completed, 1))
        p99r = res.client("svc").p(99, 0.3) / b99
        print(f"slip={slip:.2f}: capacity saved {cap*100:5.1f}%  "
              f"energy/job saved {en*100:5.1f}%  p99 {p99r:.2f}x  "
              f"f_final {gov.current_f:.2f}  "
              f"fits {sum(f.fitted for f in rs.fits.values())} kernels")
    print("\nhigher slip => more capacity savings for more latency — the "
          "paper's k knob (§4.5/4.6).  Note energy/JOB can worsen once the "
          "slowdown eats throughput: the governor bounds per-kernel slip, "
          "not queueing amplification (the paper's conservative 1.1 choice).")


if __name__ == "__main__":
    main()
