"""Router quality: regret vs the oracle placement (carried from PR 1).

Runs the adversarial 6-tenant mix (``scenarios.adversarial_router_apps``)
on a 2-device node under each placement router, then brute-forces every
placement (tenant 0 pinned to device 0 — the node is uniform, so mirrored
placements are equivalent) to find the oracle.  Score is the mean HP SLO
attainment across the four services; regret is ``oracle - router`` in SLO
points.  The vectorized engine makes the 32-placement sweep cheap.

The mix is built so the informed routers genuinely rank differently: an
idle tenant's 24-slice *reservation* (invisible to demand pricing) is
what starves a co-located hot service.  least_loaded prices that decoy
by its tiny load and parks a hot service next to it; quota_aware honors
the guarantee but packs both hot services onto one device's headroom;
affinity herds the hot services' config group together, accidentally
isolating them from the decoy (consistently the best of the three,
still double-digit SLO points short of oracle).  The bench fails if the
informed routers collapse onto one placement or one score — that would
mean the scenario stopped discriminating.

    PYTHONPATH=src python benchmarks/bench_router_regret.py \
        [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import numpy as np

from benchmarks.scenarios import DEV, adversarial_router_apps, fmt_csv
from repro.core.lithos import evaluate
from repro.core.node import place
from repro.core.types import NodeSpec, Priority

ROUTERS = ["round_robin", "least_loaded", "quota_aware", "affinity"]
SEED = 13


def score(res, apps) -> float:
    """Mean HP SLO attainment — the objective the oracle maximizes."""
    slo = [res.client(a.name).slo_attainment(a.slo_latency)
           for a in apps if a.priority == Priority.HIGH]
    return float(np.mean(slo))


def run_placement(node, apps, placement, horizon):
    res = evaluate("lithos", node, apps, horizon=horizon, seed=SEED,
                   placement=placement, engine="vec",
                   collect_records=False)
    hp99 = [res.client(a.name).p99 for a in apps
            if a.priority == Priority.HIGH]
    return score(res, apps), float(max(hp99))


def all_placements(n_apps: int, n_devices: int):
    """Every assignment with tenant 0 pinned to device 0 (uniform node:
    relabeling devices is a symmetry)."""
    for mask in range(n_devices ** (n_apps - 1)):
        pl, m = [0], mask
        for _ in range(n_apps - 1):
            pl.append(m % n_devices)
            m //= n_devices
        yield pl


def run(quick: bool = False, json_out: bool = False):
    rows = [fmt_csv("bench", "router", "metric", "value", "unit")]
    horizon = 2.0 if quick else 6.0
    node = NodeSpec.uniform(2, DEV)
    apps = adversarial_router_apps(DEV)

    routed = {r: place(node, apps, r) for r in ROUTERS}
    results = {r: run_placement(node, apps, pl, horizon)
               for r, pl in routed.items()}

    oracle_pl, oracle_score, oracle_p99 = None, -1.0, float("inf")
    for pl in all_placements(len(apps), node.n_devices):
        s, p99 = run_placement(node, apps, pl, horizon)
        if (s, -p99) > (oracle_score, -oracle_p99):
            oracle_pl, oracle_score, oracle_p99 = pl, s, p99

    for r in ROUTERS:
        s, p99 = results[r]
        rows.append(fmt_csv("router_regret", r, "placement",
                            "|".join(map(str, routed[r])), "app->dev"))
        rows.append(fmt_csv("router_regret", r, "mean_hp_slo",
                            f"{s * 100:.1f}", "%"))
        rows.append(fmt_csv("router_regret", r, "worst_hp_p99",
                            f"{p99 * 1e3:.2f}", "ms"))
        rows.append(fmt_csv("router_regret", r, "regret_vs_oracle",
                            f"{(oracle_score - s) * 100:.1f}", "SLO pts"))
    rows.append(fmt_csv("router_regret", "oracle", "placement",
                        "|".join(map(str, oracle_pl)), "app->dev"))
    rows.append(fmt_csv("router_regret", "oracle", "mean_hp_slo",
                        f"{oracle_score * 100:.1f}", "%"))
    rows.append(fmt_csv("router_regret", "oracle", "worst_hp_p99",
                        f"{oracle_p99 * 1e3:.2f}", "ms"))
    for r in rows:
        print(r)

    if json_out:
        from benchmarks._persist import csv_rows_to_results, write_json
        write_json("router_regret", csv_rows_to_results(rows),
                   {"horizon_s": horizon, "quick": quick, "seed": SEED,
                    "node": "2x a100_like", "n_tenants": len(apps),
                    "objective": "mean_hp_slo_attainment"})

    informed = ["least_loaded", "quota_aware", "affinity"]
    failures = []
    if len({tuple(routed[r]) for r in informed}) < 2:
        failures.append("informed routers collapsed onto one placement")
    if len({round(results[r][0], 3) for r in informed}) < 2:
        failures.append("informed routers all scored identically "
                        f"({ {r: results[r][0] for r in informed} })")
    if failures:
        raise RuntimeError("; ".join(failures))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="short horizon")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_ROUTER_REGRET.json")
    args = ap.parse_args()
    run(quick=args.smoke, json_out=args.json)
