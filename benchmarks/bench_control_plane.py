"""Control-plane benchmark: submission-to-admission latency + crash recovery.

Three numbers matter for an online control plane and this bench measures
all of them against the real daemon code paths (no mocks):

* **submit -> admit latency** — wall time from the submit record hitting
  the journal's inbox to the daemon journaling ``ADMIT``, measured per job
  while the node is live and stepping;
* **recovery time** — wall time for a fresh daemon incarnation to replay
  the journal of a crashed one (jobs abandoned mid-RUNNING) and bring
  every interrupted job back to RUNNING;
* **replay throughput** — journal records folded per second, the term that
  bounds recovery as the journal grows.

Usage:
    PYTHONPATH=src python benchmarks/bench_control_plane.py [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from _persist import write_json                              # noqa: E402
from repro.ctl import store                                  # noqa: E402
from repro.ctl.daemon import ControlPlane, DaemonConfig      # noqa: E402
from repro.ctl.state import JobState                         # noqa: E402
from repro.ctl.store import Journal, replay                  # noqa: E402

PRESETS = {
    "full": {"n_submits": 24, "n_crash_jobs": 4, "replay_records": 20000},
    "smoke": {"n_submits": 6, "n_crash_jobs": 2, "replay_records": 2000},
}


def _tick_until(cp, pred, max_wall=120.0):
    t0 = time.time()
    while time.time() - t0 < max_wall:
        cp.tick()
        if pred():
            return
    raise RuntimeError("daemon did not converge")


def bench_admission(n_submits: int) -> dict:
    """Per-job wall latency from inbox write to the journaled ADMIT."""
    d = tempfile.mkdtemp(prefix="ctl-bench-")
    try:
        cp = ControlPlane(d, DaemonConfig(n_devices=2, poll_interval=0.0))
        lats = []
        for i in range(n_submits):
            jid = store.request_submit(
                d, {"kind": "serve", "rps": 10.0, "duration": 0.25,
                    "priority": "be", "name": f"bench-{i}"})
            t_sub = time.time()
            _tick_until(cp, lambda: cp.jobs.get(jid) is not None
                        and cp.jobs[jid].state not in (JobState.QUEUED,))
            lats.append(time.time() - t_sub)
        _tick_until(cp, lambda: all(j.terminal for j in cp.jobs.values()))
        cp.shutdown()
        arr = 1e3 * np.asarray(lats)
        return {"metric": "submit_to_admit_ms", "n": len(lats),
                "p50": round(float(np.median(arr)), 3),
                "p95": round(float(np.percentile(arr, 95)), 3),
                "max": round(float(arr.max()), 3)}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def bench_recovery(n_jobs: int) -> dict:
    """Crash with ``n_jobs`` RUNNING, then time a fresh incarnation until
    all of them are RUNNING again (replay + requeue + re-admission)."""
    d = tempfile.mkdtemp(prefix="ctl-bench-")
    try:
        for i in range(n_jobs):
            store.request_submit(
                d, {"kind": "serve", "rps": 10.0, "duration": 60.0,
                    "priority": "be", "name": f"crash-{i}"})
        cp = ControlPlane(d, DaemonConfig(n_devices=2, poll_interval=0.0))
        _tick_until(cp, lambda: sum(
            j.state is JobState.RUNNING for j in cp.jobs.values()) == n_jobs)
        cp.journal.close()      # crash: no shutdown hook, jobs left RUNNING
        del cp

        t0 = time.time()
        cp2 = ControlPlane(d, DaemonConfig(n_devices=2, poll_interval=0.0))
        t_replay = time.time() - t0
        assert all(j.recoveries == 1 for j in cp2.jobs.values())
        _tick_until(cp2, lambda: sum(
            j.state is JobState.RUNNING for j in cp2.jobs.values()) == n_jobs)
        t_running = time.time() - t0
        cp2.shutdown()
        return {"metric": "crash_recovery_ms", "n_jobs": n_jobs,
                "replay_ms": round(1e3 * t_replay, 3),
                "all_running_ms": round(1e3 * t_running, 3)}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def bench_replay_throughput(n_records: int) -> dict:
    """Fold rate of the journal reader (bounds recovery on long histories)."""
    d = tempfile.mkdtemp(prefix="ctl-bench-")
    try:
        j = Journal(d)
        per_job = 4                     # submit/admit/start/finish
        for i in range(n_records // per_job):
            jid = f"job-{i:06d}"
            j.append(jid, store.SUBMIT, spec={"kind": "train"})
            j.append(jid, "admit", cid=i, device=i % 2)
            j.append(jid, "start", granted=0, admitted_sim=0.0, ends_sim=1.0)
            j.append(jid, "finish", result={"n_completed": 1})
        j.close()
        t0 = time.time()
        jobs = replay(d)
        dt = time.time() - t0
        n = per_job * (n_records // per_job)
        assert len(jobs) == n_records // per_job
        assert all(jb.state is JobState.DONE for jb in jobs.values())
        return {"metric": "replay_throughput", "records": n,
                "seconds": round(dt, 4),
                "records_per_sec": round(n / dt, 1)}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small preset for CI")
    ap.add_argument("--json", action="store_true",
                    help="persist BENCH_CONTROL_PLANE.json via _persist")
    args = ap.parse_args(argv)
    preset = PRESETS["smoke" if args.smoke else "full"]

    results = [bench_admission(preset["n_submits"]),
               bench_recovery(preset["n_crash_jobs"]),
               bench_replay_throughput(preset["replay_records"])]
    for r in results:
        print(r)
    if args.json:
        write_json("control_plane", results,
                   meta={"preset": "smoke" if args.smoke else "full",
                         **preset})


if __name__ == "__main__":
    main()
