"""Figs 13-15 — inference-only multitenancy: 2 HP + 1 BE across systems.

HP A has a latency SLO, HP B a throughput SLO, BE runs open-loop llm
inference.  Reports SLO attainment, normalized aggregate throughput,
per-app goodput, and HP A P99 by system — the paper's headline comparison
(LithOS: 100% SLO at throughput ~1; MPS 13x worse tails; 3x better tails
and 1.6x more throughput than best SotA)."""
from __future__ import annotations

from dataclasses import replace
from itertools import product

import numpy as np

from benchmarks.scenarios import (DEV, calibrated, fmt_csv, frac_throughput,
                                  hp_services)
from repro.core.lithos import evaluate, run_alone
from repro.core.types import Priority
from repro.core.workloads import mean_demand

SYSTEMS = ["lithos", "mps", "mig", "limits", "timeslice", "priority",
           "reef", "tgs", "orion"]


def combos(quick: bool):
    hp = hp_services()
    hpa_pool = ["resnet", "bert"] if quick else ["resnet", "retinanet",
                                                 "bert", "llama3", "gptj"]
    hpb_pool = ["llama3"] if quick else ["llama3", "gptj", "bert"]
    be_pool = ["gptj"] if quick else ["gptj", "llama3", "bert"]
    out = []
    for a, b, c in product(hpa_pool, hpb_pool, be_pool):
        if len({a, b, c}) < 3:
            continue
        out.append((a, b, c))
    return out[:2] if quick else out[:4]


def setup(hp, a_name, b_name, be_name):
    hpa = calibrated(replace(hp[a_name], name="hpA",
                             quota_slices=int(DEV.n_slices * 0.75)), 0.5,
                     slo_mult=4.0)
    hpb = calibrated(replace(hp[b_name], name="hpB", decode_tokens=6,
                             quota_slices=DEV.n_slices
                             - int(DEV.n_slices * 0.75)), 0.15, slo_mult=10.0)
    # BE: two closed-loop LLM streams with long prompts — the multi-ms
    # prefill kernels that cause HoL blocking (Fig 10b); two streams so
    # unprioritized systems feel sustained pressure (a BE inference server
    # runs many concurrent requests)
    be = replace(hp[be_name], name="be", priority=Priority.BEST_EFFORT,
                 quota_slices=0, rps=0.0, fusion=16,
                 prompt_mix=((8192, 1.0),))
    be2 = replace(be, name="be2", seed=97)
    return hpa, hpb, be, be2


def run(quick: bool = False, json_out: bool = False):
    rows = [fmt_csv("bench", "system", "metric", "value", "unit")]
    horizon = 6.0 if quick else 12.0
    hp = hp_services()
    agg: dict[str, list] = {s: [] for s in SYSTEMS}
    for (a_name, b_name, be_name) in combos(quick):
        hpa, hpb, be, be2 = setup(hp, a_name, b_name, be_name)
        # normalization baselines (solo runs; fractional counting for the
        # long-pipeline LLM apps)
        solo_a = run_alone(DEV, hpa, horizon=horizon, seed=11)
        solo_b = run_alone(DEV, hpb, horizon=horizon, seed=11)
        solo_be = run_alone(DEV, be, horizon=horizon, seed=11)
        thr_a_alone = max(solo_a.client("hpA").throughput, 1e-9)
        thr_b_alone = max(frac_throughput(solo_b, "hpB", horizon), 1e-9)
        thr_be_alone = max(frac_throughput(solo_be, "be", horizon), 1e-9)
        for system in SYSTEMS:
            res = evaluate(system, DEV, [hpa, hpb, be, be2],
                           horizon=horizon, seed=11)
            A, B = res.client("hpA"), res.client("hpB")
            slo_a = A.slo_attainment(hpa.slo_latency)
            slo_b = (frac_throughput(res, "hpB", horizon) /
                     thr_b_alone)
            thr = ((A.throughput / thr_a_alone) +
                   frac_throughput(res, "hpB", horizon)
                   / thr_b_alone) / 2.0
            goodput_a = A.goodput(hpa.slo_latency, horizon) / max(
                hpa.rps, 1e-9)
            be_thr = (frac_throughput(res, "be", horizon)
                      + frac_throughput(res, "be2", horizon)
                      ) / thr_be_alone
            p99 = A.p99
            agg[system].append(dict(slo_a=slo_a, slo_b=min(slo_b, 1.5),
                                    thr=thr, be=be_thr, p99=p99,
                                    goodput_a=goodput_a,
                                    combo=f"{a_name}+{b_name}+{be_name}"))
    for system in SYSTEMS:
        if not agg[system]:
            continue
        m = lambda k: float(np.mean([x[k] for x in agg[system]]))
        rows.append(fmt_csv("fig13", system, "slo_attainment_hpA",
                            f"{m('slo_a')*100:.1f}", "%"))
        rows.append(fmt_csv("fig13", system, "hpB_throughput_vs_alone",
                            f"{m('slo_b'):.2f}", "x"))
        rows.append(fmt_csv("fig13", system, "agg_hp_throughput",
                            f"{m('thr'):.2f}", "x"))
        rows.append(fmt_csv("fig14", system, "be_throughput_vs_alone",
                            f"{m('be'):.2f}", "x"))
        rows.append(fmt_csv("fig15", system, "hpA_p99",
                            f"{m('p99')*1e3:.1f}", "ms"))
    for r in rows:
        print(r)
    # derived paper-claim ratios
    get = lambda s, k: float(np.mean([x[k] for x in agg[s]]))
    if agg["lithos"] and agg["mps"]:
        print(fmt_csv("fig15", "derived", "mps_p99_over_lithos",
                      f"{get('mps','p99')/max(get('lithos','p99'),1e-9):.1f}",
                      "x  (paper: 13x)"))
        sota = min((s for s in SYSTEMS if s not in ("lithos",)),
                   key=lambda s: get(s, "p99") if agg[s] else 1e9)
        print(fmt_csv("fig15", "derived", f"best_sota({sota})_p99_over_lithos",
                      f"{get(sota,'p99')/max(get('lithos','p99'),1e-9):.1f}",
                      "x  (paper: 3x vs best SotA)"))
    if json_out:
        from benchmarks._persist import csv_rows_to_results, write_json
        write_json("inference_stacking", csv_rows_to_results(rows),
                   {"horizon_s": horizon, "quick": quick, "seed": 11,
                    "systems": SYSTEMS,
                    "combos": [x["combo"] for x in agg["lithos"]]})
    return rows


if __name__ == "__main__":
    run()
