"""Fig 19 — feature ablation on the hybrid inference/training stack.

Configurations: scheduler-only (quotas, no stealing/atomization) ->
+stealing -> +atomization (full).  Paper: TPC scheduler brings HP tails to
~1.38x ideal; atomization to ~1.19x."""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.scenarios import (DEV, be_trainers, calibrated, fmt_csv,
                                  frac_throughput, hp_services)
from repro.core.lithos import evaluate, run_alone
from repro.core.scheduler import LithOSConfig

# the paper's progression: unmanaged sharing -> TPC scheduler (quotas +
# stealing) -> + kernel atomization (full LithOS)
VARIANTS = {
    "baseline(mps)": None,                    # no quota enforcement
    "tpc_scheduler": LithOSConfig(atomize=False, steal=True),
    "+atomization(full)": LithOSConfig(atomize=True, steal=True),
}


def run(quick: bool = False):
    rows = [fmt_csv("bench", "variant", "metric", "value", "unit")]
    horizon = 6.0 if quick else 12.0
    hp = calibrated(replace(hp_services()["bert"], name="hp",
                            quota_slices=DEV.n_slices), 0.8)
    be = replace(be_trainers()["llama_ft"], name="be")
    ideal = max(run_alone(DEV, hp, horizon=horizon, seed=51).client("hp").p99,
                1e-9)
    solo_be = run_alone(DEV, be, horizon=horizon, seed=51)
    be_alone = max(frac_throughput(solo_be, "be", horizon), 1e-9)
    for name, cfgv in VARIANTS.items():
        system = "mps" if cfgv is None else "lithos"
        res = evaluate(system, DEV, [hp, be], horizon=horizon, seed=51,
                       lithos_config=cfgv)
        H, E = res.client("hp"), res.client("be")
        rows.append(fmt_csv("fig19", name, "hp_p99_vs_ideal",
                            f"{H.p99/ideal:.2f}", "x"))
        rows.append(fmt_csv("fig19", name, "hp_throughput_vs_load",
                            f"{H.throughput/max(hp.rps,1e-9):.2f}", "x"))
        rows.append(fmt_csv("fig19", name, "be_throughput_vs_alone",
                            f"{frac_throughput(res, 'be', horizon)/be_alone:.2f}",
                            "x"))
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    run()
