"""Shared workload scenarios for the paper-figure benchmarks.

The paper's models map onto the assigned architectures (§6 of the paper ->
DESIGN.md §4): the evaluation device is A100-calibrated (54 slices = the
paper's 54 TPCs) so Table 1/2 regimes carry over.

    paper model        stand-in (assigned arch)        role
    ResNet-50          olmo-1b      fwd_infer          HP A (tight SLO)
    RetinaNet          llava-next-34b fwd_infer        HP A (loose SLO)
    BERT-Large         whisper-small fwd_infer         HP A/B
    Llama 3 8B         llama3-8b    llm_infer          HP A/B / BE
    GPT-J 6B           qwen2-moe-a2.7b llm_infer       HP B / BE
    VGG/ResNet/... trainers -> olmo/xlstm/rgemma/qwen2moe/llama trainers

Loads are calibrated from the cost model (workloads.mean_demand) to the
paper's operating points; SLO constraints are 3-5x the solo service time,
mirroring MLPerf-datacenter style constraints.
"""
from __future__ import annotations

from dataclasses import replace

from repro.configs.registry import get_config
from repro.core.types import DeviceSpec, Priority
from repro.core.workloads import AppSpec, mean_demand

DEV = DeviceSpec.a100_like()


def _app(name, arch, kind, **kw):
    return AppSpec(name, get_config(arch), kind, **kw)


# -- HP inference services (Table 2 analogues) ------------------------------

def hp_services() -> dict[str, AppSpec]:
    return {
        "resnet": _app("resnet", "olmo-1b", "fwd_infer",
                       priority=Priority.HIGH, batch=8, fusion=8,
                       prompt_mix=((128, 1.0),)),
        "retinanet": _app("retinanet", "llava-next-34b", "fwd_infer",
                          priority=Priority.HIGH, batch=1, fusion=12,
                          prompt_mix=((576, 1.0),)),
        "bert": _app("bert", "whisper-small", "fwd_infer",
                     priority=Priority.HIGH, batch=8, fusion=8,
                     prompt_mix=((384, 1.0),)),
        "llama3": _app("llama3", "llama3-8b", "llm_infer",
                       priority=Priority.HIGH, fusion=8,
                       prompt_mix=((512, 0.6), (2048, 0.4)),
                       decode_tokens=8),
        "gptj": _app("gptj", "qwen2-moe-a2.7b", "llm_infer",
                     priority=Priority.HIGH, fusion=8,
                     prompt_mix=((512, 0.6), (2048, 0.4)),
                     decode_tokens=8),
    }


# -- BE training jobs (Table 1 analogues) -----------------------------------

def be_trainers() -> dict[str, AppSpec]:
    """Step times calibrated to Table 1 (74-690 ms per iteration)."""
    mk = lambda name, arch, b, s, f=8: _app(
        name, arch, "train", priority=Priority.BEST_EFFORT,
        train_batch=b, train_seq=s, fusion=f)
    return {
        "olmo_train": mk("olmo_train", "olmo-1b", 8, 1024),       # ~0.2 s
        "xlstm_train": mk("xlstm_train", "xlstm-1.3b", 8, 1024),
        "rgemma_train": mk("rgemma_train", "recurrentgemma-9b", 2, 1024, 12),
        "moe_train": mk("moe_train", "qwen2-moe-a2.7b", 8, 1024),
        "whisper_train": mk("whisper_train", "whisper-small", 64, 448),
        "llama_ft": mk("llama_ft", "llama3-8b", 2, 2048, 10),     # ~0.6 s
    }


def calibrated(app: AppSpec, target_util: float, device=DEV,
               slo_mult: float = 4.0) -> AppSpec:
    """Set Poisson rate for a target solo utilization and an SLO at
    slo_mult x the solo service time (inference apps only)."""
    if app.kind == "train":
        return app
    demand = mean_demand(app, device)
    rps = target_util / demand
    return replace(app, rps=rps, slo_latency=slo_mult * demand)


def fmt_csv(*cols) -> str:
    return ",".join(str(c) for c in cols)


# -- multi-device node scenarios (node layer benchmarks) --------------------

def node_stacking_apps(device=DEV, *, n_hp: int = 3,
                       n_be: int = 2) -> list:
    """A multi-tenant node mix: HP inference services with calibrated loads
    and SLOs (inference stacking) plus closed-loop BE trainers (hybrid
    stacking).  Per-device quotas stay derived (each device splits itself
    among the HP tenants the router places there)."""
    hp = hp_services()
    be = be_trainers()
    # short-service apps first so small-n_hp (smoke) scenarios complete
    # jobs within short horizons; the heavy LLM tenants join at n_hp >= 3
    pool = [
        calibrated(replace(hp["resnet"], name="hpA"), 0.45,
                   device=device, slo_mult=4.0),
        calibrated(replace(hp["bert"], name="hpB"), 0.35,
                   device=device, slo_mult=4.0),
        calibrated(replace(hp["llama3"], name="hpC", decode_tokens=6), 0.25,
                   device=device, slo_mult=8.0),
        calibrated(replace(hp["gptj"], name="hpD", decode_tokens=6), 0.2,
                   device=device, slo_mult=8.0),
    ]
    trainers = [replace(be["olmo_train"], name="beA"),
                replace(be["llama_ft"], name="beB"),
                replace(be["xlstm_train"], name="beC")]
    return pool[:n_hp] + trainers[:n_be]


def adversarial_router_apps(device=DEV) -> list:
    """A 6-tenant mix built so the informed routers genuinely disagree
    (the router-regret benchmark's input).

    * ``heavyA``/``heavyB`` — two hot olmo services (~0.5 solo util each).
      The only good placements keep them apart.
    * ``decoy`` — a near-idle whisper service holding a 24-slice quota.
      ``quota_aware`` reserves for the guarantee first, then packs both
      heavies onto the other device's headroom; ``least_loaded`` prices
      the decoy by its actual (tiny) load and splits the heavies.
    * ``light`` — a small whisper service, padding for the quota headroom
      accounting.
    * ``trainerA``/``trainerB`` — two olmo BE trainers (closed-loop, so
      they price at full-device demand and anchor one device each under
      ``least_loaded``).  They share the heavies' model config, so
      ``affinity`` herds all four olmo tenants onto one device.

    The trap: the decoy's *reservation* (not its load) is what starves a
    co-located heavy — 24 reserved slices leave a 30-slice headroom that
    derived HP shares then split.  ``least_loaded`` prices the decoy at
    0.15 and parks a heavy next to it; ``quota_aware`` respects the
    guarantee but packs both heavies onto one device's headroom;
    ``affinity`` herds the olmo tenants together, which accidentally
    isolates the heavies from the decoy (consistently the best of the
    three, still short of oracle).  Three informed routers, three
    genuinely different placements and scores."""
    hp = hp_services()
    be = be_trainers()
    return [
        calibrated(replace(hp["resnet"], name="heavyA"), 0.5,
                   device=device),
        calibrated(replace(hp["resnet"], name="heavyB"), 0.5,
                   device=device),
        calibrated(replace(hp["bert"], name="decoy", quota_slices=24),
                   0.15, device=device),
        calibrated(replace(hp["bert"], name="light"), 0.1, device=device),
        replace(be["olmo_train"], name="trainerA"),
        replace(be["olmo_train"], name="trainerB"),
    ]


def calibrated_solo_run(app: AppSpec, lithos_config, *, horizon: float,
                        cal_horizon: float, seed: int, device=DEV):
    """Two-phase solo run: a calibration sim lets the predictor /
    right-sizer / governor learn (probes, f-exploration), then a fresh
    measurement sim reuses the learned state with probing disabled — the
    steady state a minutes-long production run reaches (the paper's
    measurement regime; our sim horizons are seconds)."""
    import dataclasses as _dc

    from repro.core.lithos import make_policy, run_alone
    from repro.core.simulator import Simulator
    from repro.core.types import Priority

    solo = replace(app, quota_slices=device.n_slices)
    cal_policy = make_policy("lithos", device, [solo],
                             lithos_config=lithos_config)
    Simulator(device, [solo], cal_policy, horizon=cal_horizon,
              seed=seed + 1).run()
    meas_cfg = _dc.replace(lithos_config, probe_low=False)
    policy = make_policy("lithos", device, [solo], lithos_config=meas_cfg)
    policy.predictor = cal_policy.predictor
    policy.rightsizer = cal_policy.rightsizer
    policy.governor = cal_policy.governor
    policy.governor.current_f = 1.0
    policy.governor.last_switch = -1e9
    sim = Simulator(device, [solo], policy, horizon=horizon, seed=seed)
    res = sim.run()
    res.policy = policy
    return res


def frac_throughput(res, cid_name: str, horizon: float) -> float:
    """Jobs/s including fractional progress (kernel completions / kernels
    per job) — closed-loop BE trainers complete few whole steps in short
    sim horizons, so whole-job counting quantizes harshly.

    Kernels-per-job comes from the simulated client's *own* issued jobs
    (``ClientMetrics.kernels_per_job``), never from resampling the trace:
    a fresh RNG stream is exact only for deterministic train traces and
    biased for stochastic LLM traces (geometric decode lengths)."""
    # client ids are node-global and need not equal list position
    cm = next(c for c in res.clients if c.name == cid_name)
    per_job = max(1.0, cm.kernels_per_job)
    cid = cm.cid
    kernels = sum(1 for r in res.records
                  if r.task.client_id == cid and r.task.atom_of is None)
    atoms = {}
    for r in res.records:
        if r.task.client_id == cid and r.task.atom_of is not None:
            parent, idx, n = r.task.atom_of
            atoms.setdefault(parent, 0)
            atoms[parent] += 1.0 / n
    kernels += sum(atoms.values())
    return kernels / per_job / horizon
