"""Node-scale stacking: multi-device placement × system comparison.

Scales the paper's single-GPU stacking studies (Figs 13–16) to a
multi-device node: N A100-calibrated devices, 4+ tenants mixing calibrated
HP inference services (inference stacking) with closed-loop BE trainers
(hybrid stacking), routed by the node layer's placement policies.

Reports, per (router, system):
  * HP SLO attainment and P99 per service
  * BE throughput (fractional kernel counting — short horizons)
  * node utilization and energy
  * the placement each router chose

Headline expectations: lithos beats mps on HP tails at equal BE progress on
every placement; mig strands BE entirely; an informed router (least_loaded /
quota_aware) beats round_robin by not co-locating the two heaviest tenants.

    PYTHONPATH=src python benchmarks/bench_node_stacking.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.scenarios import DEV, fmt_csv, frac_throughput, \
    node_stacking_apps
from repro.core.lithos import evaluate
from repro.core.types import NodeSpec, Priority

SYSTEMS = ["lithos", "mps", "mig"]
ROUTERS = ["round_robin", "least_loaded", "quota_aware", "affinity"]


def run_node(node: NodeSpec, apps, horizon: float, seed: int,
             rows: list[str], tag: str):
    for router in ROUTERS:
        for system in SYSTEMS:
            res = evaluate(system, node, apps, horizon=horizon, seed=seed,
                           router=router)
            placement = "|".join(str(d) for d in res.placement)
            rows.append(fmt_csv(tag, router, system, "placement",
                                placement, "app->dev"))
            hp_slo, be_thr = [], []
            for app in apps:
                cm = res.client(app.name)
                if app.priority == Priority.HIGH:
                    slo = cm.slo_attainment(app.slo_latency)
                    hp_slo.append(slo)
                    rows.append(fmt_csv(tag, router, system,
                                        f"{app.name}_p99",
                                        f"{cm.p99 * 1e3:.2f}", "ms"))
                    rows.append(fmt_csv(tag, router, system,
                                        f"{app.name}_slo",
                                        f"{slo * 100:.1f}", "%"))
                else:
                    thr = frac_throughput(res, app.name, horizon)
                    be_thr.append(thr)
                    rows.append(fmt_csv(tag, router, system,
                                        f"{app.name}_throughput",
                                        f"{thr:.3f}", "jobs/s"))
            if system == "lithos":
                # CI guard: under lithos every HP tenant must make progress
                # (nan metrics from zero completions would pass silently)
                starved = [a.name for a in apps
                           if a.priority == Priority.HIGH
                           and res.client(a.name).n_completed == 0]
                if starved:
                    raise RuntimeError(
                        f"{tag}/{router}: HP tenants starved under lithos: "
                        f"{starved}")
            mean = lambda xs: sum(xs) / max(1, len(xs))
            rows.append(fmt_csv(tag, router, system, "mean_hp_slo",
                                f"{mean(hp_slo) * 100:.1f}", "%"))
            rows.append(fmt_csv(tag, router, system, "agg_be_throughput",
                                f"{sum(be_thr):.3f}", "jobs/s"))
            rows.append(fmt_csv(tag, router, system, "node_utilization",
                                f"{res.utilization * 100:.1f}", "%"))
            rows.append(fmt_csv(tag, router, system, "node_energy",
                                f"{res.energy:.0f}", "J"))


def run(quick: bool = False, json_out: bool = False):
    rows = [fmt_csv("bench", "router", "system", "metric", "value", "unit")]
    horizon = 3.0 if quick else 10.0
    apps4 = node_stacking_apps(DEV, n_hp=2, n_be=2)       # 4 tenants
    run_node(NodeSpec.uniform(2, DEV), apps4, horizon, 11, rows,
             "node2x4t")
    if not quick:
        apps7 = node_stacking_apps(DEV, n_hp=4, n_be=3)   # 7 tenants
        run_node(NodeSpec.uniform(3, DEV), apps7, horizon, 11, rows,
                 "node3x7t")
    for r in rows:
        print(r)
    if json_out:
        from benchmarks._persist import csv_rows_to_results, write_json
        write_json("node_stacking", csv_rows_to_results(rows),
                   {"horizon_s": horizon, "quick": quick, "seed": 11,
                    "systems": SYSTEMS, "routers": ROUTERS,
                    "device": "a100_like"})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short horizons, 2-device scenario only")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_NODE_STACKING.json")
    args = ap.parse_args()
    run(quick=args.smoke, json_out=args.json)
