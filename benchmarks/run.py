"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig16,...]
                                            [--json]

Prints CSV rows (bench,case,...,value,unit) per figure plus derived
paper-claim comparisons; exits non-zero if any module crashes.

``--json`` also persists results through benchmarks._persist for the
modules that support it (sim_throughput writes BENCH_SIM.json and
cluster writes BENCH_CLUSTER.json — the committed perf trajectories —
the node/cluster/figure benches write their own BENCH_*.json
artifacts)."""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

MODULES = [
    ("fig10_kernel_latency", "benchmarks.bench_kernel_latency"),
    ("fig11_scaling_curves", "benchmarks.bench_scaling_curves"),
    ("fig12_freq_curves", "benchmarks.bench_freq_curves"),
    ("fig13-15_inference_stacking", "benchmarks.bench_inference_stacking"),
    ("fig16_hybrid_stacking", "benchmarks.bench_hybrid_stacking"),
    ("fig17_rightsizing", "benchmarks.bench_rightsizing"),
    ("fig18_dvfs", "benchmarks.bench_dvfs"),
    ("fig19_ablation", "benchmarks.bench_ablation"),
    ("fig20_atomization", "benchmarks.bench_atomization"),
    ("sec7.4_predictor", "benchmarks.bench_predictor"),
    ("pallas_atoms", "benchmarks.bench_pallas_atoms"),
    ("node_stacking", "benchmarks.bench_node_stacking"),
    ("node_stealing", "benchmarks.bench_node_stealing"),
    ("router_regret", "benchmarks.bench_router_regret"),
    ("cluster", "benchmarks.bench_cluster"),
    ("sim_throughput", "benchmarks.bench_sim_throughput"),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced combination grids / shorter horizons")
    ap.add_argument("--only", default="",
                    help="comma-separated substring filters on module names")
    ap.add_argument("--json", action="store_true",
                    help="persist results via benchmarks._persist where "
                         "the module supports it")
    args = ap.parse_args(argv)
    only = [s for s in args.only.split(",") if s]

    failures = []
    t_all = time.time()
    for name, module in MODULES:
        if only and not any(o in name for o in only):
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            kw = {"quick": args.quick}
            if (args.json and "json_out"
                    in inspect.signature(mod.run).parameters):
                kw["json_out"] = True
            mod.run(**kw)
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:                        # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"[{name}] FAILED", flush=True)
    print(f"\n===== benchmarks finished in {time.time()-t_all:.1f}s; "
          f"{len(failures)} failures {failures} =====")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
