"""Cluster tier at scale: managed placement vs placement-only.

The scenario is the canonical stale-forecast fleet: inference services were
provisioned onto a block of "service" devices (calibrated to ~0.85 offered
utilization), best-effort trainers were parked on their own block, and one
node was provisioned for growth that never came — it sits empty.  A
placement-only control plane is stuck with that shape; the managed cluster
tier is not:

  * cross-node stealing migrates trainers from their saturated block into
    the empty node (the PR 2 lending protocol, one level up), and
  * the cluster power manager plans per-device DVFS states under a watts
    budget set to 93% of the unmanaged draw — best-effort-only devices
    throttle first, service devices keep ``power_hp_floor``.

Both arms run the same pinned placement, the same cluster-global client
ids (identical workload streams) and the vectorized engine with
``collect_records=False``.  Presets:

  * ``full``  — 4 nodes x 2 A100s, 2048 services + 8 trainers, >= 1M
    requests (the committed BENCH_CLUSTER.json trajectory).  The managed
    arm must strictly improve at least 2 of the 4 headline metrics:
    aggregate throughput, pooled HP P99.9, mean fragmentation, joules.
  * ``smoke`` — 3 nodes x 1 A100, 12 services + 2 trainers, ~8k requests
    (CI perf-smoke; asserts an absolute events/sec floor).

    PYTHONPATH=src python benchmarks/bench_cluster.py \
        [--preset full|smoke] [--min-events-per-sec N] [--json]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import numpy as np

from benchmarks.scenarios import DEV, fmt_csv
from repro.configs.registry import get_config
from repro.core import types as T
from repro.core.cluster import evaluate_cluster
from repro.core.types import (ClusterConfig, ClusterSpec, NodeConfig,
                              NodeSpec, Priority)
from repro.core.workloads import cluster_trace_apps

PRESETS = {
    # name: (n_nodes, devs_per_node, service_nodes, be_nodes, n_services,
    #        be_per_service_device, total_requests)
    "full": (4, 2, (0, 1), (2,), 2048, 2, 1_000_000),
    "smoke": (3, 1, (0,), (1,), 12, 2, 8_000),
}
SEED = 11
CAP_FRACTION = 0.93          # managed power budget vs unmanaged mean draw

MANAGED = dict(migration=True, epoch=0.5, migration_cost=0.25,
               cooldown=2.0, hp_depth_hi=4, free_lo=0.125, free_hi=0.5,
               node_config=NodeConfig(migration=True))


def build(preset: str):
    n_nodes, devs, svc_nodes, be_nodes, n_services, be_per, reqs = \
        PRESETS[preset]
    cluster = ClusterSpec.uniform(n_nodes, NodeSpec.uniform(devs, DEV))
    svc_devs = [(n, d) for n in svc_nodes for d in range(devs)]
    be_devs = [(n, d) for n in be_nodes for d in range(devs)]
    apps, horizon = cluster_trace_apps(
        get_config("olmo-1b"), DEV, n_services=n_services,
        total_requests=reqs, n_devices=len(svc_devs),
        be_per_device=be_per)
    # pinned stale-forecast placement: services round-robin their block,
    # trainers round-robin theirs, the last node stays empty
    pl, si, bi = [], 0, 0
    for a in apps:
        if a.priority == Priority.HIGH:
            pl.append(svc_devs[si % len(svc_devs)])
            si += 1
        else:
            pl.append(be_devs[bi % len(be_devs)])
            bi += 1
    return cluster, apps, pl, horizon


def run_arm(cluster, apps, placement, horizon, cfg):
    T.reset_kernel_ids()
    t0 = time.perf_counter()
    res = evaluate_cluster("lithos", cluster, apps, horizon=horizon,
                           seed=SEED, cluster_config=cfg,
                           placement=placement, engine="vec",
                           collect_records=False)
    wall = time.perf_counter() - t0
    events = sum(s.events for nc in res.coordinator.node_coords
                 for s in nc.sims)
    hp_lat, hp_jobs, be_jobs = [], 0, 0
    for c in res.clients:
        if c.priority == Priority.HIGH:
            hp_lat.extend(c.latencies)
            hp_jobs += c.n_completed
        else:
            be_jobs += c.n_completed
    return {
        "wall_s": round(wall, 2),
        "events": events,
        "events_per_sec": round(events / wall, 1),
        "agg_throughput": (hp_jobs + be_jobs) / horizon,
        "hp_requests": hp_jobs,
        "be_jobs": be_jobs,
        "hp_p999_ms": float(np.quantile(hp_lat, 0.999)) * 1e3,
        "frag_mean": res.frag_mean,
        "joules": res.energy,
        "utilization": res.utilization,
        "migrations": res.migrations,
        "node_migrations": res.node_migrations,
        "power_epochs": len(res.power_log),
    }


def run(quick: bool = False, preset: str | None = None,
        min_events_per_sec: float = 0.0, json_out: bool = False):
    preset = preset or ("smoke" if quick else "full")
    cluster, apps, placement, horizon = build(preset)

    base = run_arm(cluster, apps, placement, horizon, ClusterConfig())
    cap = CAP_FRACTION * base["joules"] / horizon
    managed = run_arm(cluster, apps, placement, horizon,
                      ClusterConfig(power_cap=cap, **MANAGED))

    rows = [fmt_csv("bench", "arm", "metric", "value", "unit")]
    for arm, r in (("placement_only", base), ("managed", managed)):
        for metric, unit in (
                ("agg_throughput", "jobs/s"), ("hp_p999_ms", "ms"),
                ("frag_mean", "frac"), ("joules", "J"),
                ("hp_requests", "n"), ("be_jobs", "n"),
                ("utilization", "frac"), ("migrations", "n"),
                ("node_migrations", "n"), ("events", "n"),
                ("events_per_sec", "ev/s"), ("wall_s", "s")):
            v = r[metric]
            rows.append(fmt_csv("cluster", arm, metric,
                                f"{v:.4f}" if isinstance(v, float) else v,
                                unit))
    improved = {
        "agg_throughput": managed["agg_throughput"] > base["agg_throughput"],
        "hp_p999_ms": managed["hp_p999_ms"] < base["hp_p999_ms"],
        "frag_mean": managed["frag_mean"] < base["frag_mean"],
        "joules": managed["joules"] < base["joules"],
    }
    rows.append(fmt_csv("cluster", "-", "improved_metrics",
                        "|".join(k for k, v in improved.items() if v)
                        or "none", ""))
    for r in rows:
        print(r)

    if json_out:
        from benchmarks._persist import write_json
        write_json("cluster",
                   [dict(arm="placement_only", **base),
                    dict(arm="managed", **managed)],
                   {"preset": preset, "seed": SEED, "horizon_s": horizon,
                    "n_tenants": len(apps), "power_cap_w": cap,
                    "cap_fraction": CAP_FRACTION,
                    "cluster": f"{cluster.n_nodes}x"
                               f"{cluster.nodes[0].n_devices} a100_like",
                    "engine": "vec", "collect_records": False,
                    "improved": sorted(k for k, v in improved.items()
                                       if v)})

    failures = []
    if min_events_per_sec:
        eps = min(base["events_per_sec"], managed["events_per_sec"])
        if eps < min_events_per_sec:
            failures.append(f"{eps:.0f} ev/s < floor "
                            f"{min_events_per_sec:.0f}")
    if preset == "full":
        n_up = sum(improved.values())
        if n_up < 2:
            failures.append(f"managed arm improved only {n_up}/4 metrics "
                            f"({improved})")
        if managed["migrations"] == 0:
            failures.append("no cross-node migrations fired")
    if failures:
        raise RuntimeError("; ".join(failures))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="full")
    ap.add_argument("--min-events-per-sec", type=float, default=0.0,
                    help="fail if either arm is slower than this")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_CLUSTER.json via benchmarks._persist")
    a = ap.parse_args()
    run(preset=a.preset, min_events_per_sec=a.min_events_per_sec,
        json_out=a.json)
