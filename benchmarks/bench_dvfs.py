"""Fig 18 — DVFS energy savings at slip 1.1.

Each workload runs solo at f_max and under the governor; savings compare
total device energy for the same horizon, costs compare P99.  Paper: mean
~26% (up to 46%) energy saved for ~7% P99 cost."""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import numpy as np

from benchmarks.scenarios import (DEV, be_trainers, calibrated,
                                  calibrated_solo_run, fmt_csv, hp_services)
from repro.core.lithos import run_alone
from repro.core.scheduler import LithOSConfig


def run(quick: bool = False, json_out: bool = False):
    rows = [fmt_csv("bench", "case", "metric", "value", "unit")]
    cases = {**hp_services(), **be_trainers()}
    if quick:
        cases = {k: cases[k] for k in ["resnet", "llama3", "llama_ft"]}
    horizon = 5.0 if quick else 10.0
    savings, p99_costs = [], []
    for name, app in cases.items():
        # moderate load: the paper's DVFS runs are solo trace replays, not
        # near-saturation (queueing would amplify the slip into the tails)
        app = calibrated(app, 0.35)
        base = run_alone(DEV, app, horizon=horizon, seed=41,
                         lithos_config=LithOSConfig(dvfs=False))
        dv = calibrated_solo_run(
            app, LithOSConfig(dvfs=True, slip=1.1),
            horizon=horizon, cal_horizon=horizon / 2, seed=41)
        # energy per unit of completed work (throughput-fair comparison)
        e_base = base.energy / max(base.client(app.name).n_completed, 1)
        e_dv = dv.energy / max(dv.client(app.name).n_completed, 1)
        save = 1.0 - e_dv / e_base
        savings.append(save)
        rows.append(fmt_csv("fig18", name, "energy_savings_per_job",
                            f"{save*100:.1f}", "%"))
        rows.append(fmt_csv("fig18", name, "f_final",
                            f"{dv.policy.governor.current_f:.2f}", "f/fmax"))
        if app.kind != "train":
            b99, d99 = base.client(app.name).p99, dv.client(app.name).p99
            if np.isfinite(b99) and np.isfinite(d99) and b99 > 0:
                p99_costs.append(d99 / b99 - 1.0)
                rows.append(fmt_csv("fig18", name, "p99_cost",
                                    f"{(d99/b99-1)*100:.1f}", "%"))
    rows.append(fmt_csv("fig18", "derived", "mean_energy_savings",
                        f"{np.mean(savings)*100:.1f}",
                        "%  (paper: ~26%, max 46%)"))
    if p99_costs:
        rows.append(fmt_csv("fig18", "derived", "mean_p99_cost",
                            f"{np.mean(p99_costs)*100:.1f}",
                            "%  (paper: ~7%)"))
    for r in rows:
        print(r)
    if json_out:
        from benchmarks._persist import csv_rows_to_results, write_json
        write_json("dvfs", csv_rows_to_results(rows),
                   {"horizon_s": horizon, "quick": quick, "seed": 41,
                    "slip": 1.1, "cases": sorted(cases),
                    "device": "a100_like"})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3 workloads, short horizon")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_DVFS.json")
    args = ap.parse_args()
    run(quick=args.smoke, json_out=args.json)
