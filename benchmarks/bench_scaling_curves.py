"""Fig 11 — per-kernel TPC (core-slice) scaling curves + fit accuracy.

Runs each workload solo under LithOS with right-sizing probes enabled,
collects the online two-point Amdahl fits, and reports the kernel-runtime-
weighted R^2 against additional ground-truth observations (paper §7.2:
0.92-0.99)."""
from __future__ import annotations

import numpy as np

from dataclasses import replace

from benchmarks.scenarios import DEV, be_trainers, calibrated, fmt_csv, hp_services
from repro.core.costmodel import CostModel
from repro.core.lithos import run_alone
from repro.core.scheduler import LithOSConfig
from repro.core.types import Priority


def run(quick: bool = False):
    rows = [fmt_csv("bench", "case", "value", "unit")]
    cases = {**{k: v for k, v in list(hp_services().items())[:2 if quick else 5]},
             **{k: v for k, v in list(be_trainers().items())[:2 if quick else 6]}}
    cost = CostModel(DEV)
    for name, app in cases.items():
        # offline characterization: best-effort priority => full-range
        # (all-slices, 1-slice) probes, the paper's fitting protocol
        app = replace(calibrated(app, 0.5), priority=Priority.BEST_EFFORT)
        res = run_alone(DEV, app, horizon=4.0 if quick else 8.0,
                        system="lithos",
                        lithos_config=LithOSConfig(rightsize=True, probe_low=True))
        rs = res.policy.rightsizer
        # extra ground-truth points for R^2: evaluate fits vs cost model
        r2s, weights = [], []
        for key, fit in rs.fits.items():
            if not fit.fitted or fit.m <= 0:
                continue          # probe-skipped big kernels: no curve
            # reconstruct the FULL task work from any recorded completion
            # (atoms carry 1/n of the kernel's work)
            recs = [r for r in res.records if r.task.key() == key]
            if not recs:
                continue
            full = [r for r in recs if r.task.atom_of is None]
            if full:
                recs = full
            else:
                n = recs[0].task.atom_of[2]
                from dataclasses import replace as _rep
                recs = [_rep(recs[0], task=_rep(
                    recs[0].task, work=recs[0].task.work.scaled(n)))]
            w = recs[0].task.work
            # evaluate over the operational range: [min observed point,
            # occupancy bound] — the filtering heuristic (§4.5) ensures the
            # system never allocates beyond the bound, where latency is
            # flat and an Amdahl curve is meaningless
            t_lo = max(1, min(fit.points))
            t_hi = min(54, rs.occupancy_bound(recs[0].task))
            if t_hi < 16 or t_hi <= t_lo + 1:
                continue   # paper computes R^2 only "for kernels where the
                           # possible TPCs value exceeds the threshold"—short
                           # outliers are the filtering heuristic's job
            grid = sorted({t_lo, (t_lo + t_hi) // 2,
                           max(t_lo + 1, int(0.75 * t_hi)), t_hi})
            obs = {t: cost.latency(w, t) for t in grid}
            r2s.append(fit.r_squared(obs))
            weights.append(sum(r.latency for r in recs))
        if r2s:
            wavg = float(np.average(r2s, weights=weights))
            rows.append(fmt_csv("fig11", f"{name}/weighted_r2",
                                f"{wavg:.3f}", "r2"))
            rows.append(fmt_csv("fig11", f"{name}/n_kernels_fit",
                                len(r2s), "count"))
    for r in rows:
        print(r)
    vals = [float(r.split(",")[2]) for r in rows[1:] if "weighted_r2" in r]
    if vals:
        print(fmt_csv("fig11", "derived/mean_r2", f"{np.mean(vals):.3f}",
                      "r2  (paper: 0.92-0.99)"))
    return rows


if __name__ == "__main__":
    run()
