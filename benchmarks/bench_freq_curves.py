"""Fig 12 — per-kernel frequency-scaling sensitivity.

Runs workloads solo under the DVFS governor's learning protocol and
compares the learned sensitivity s per kernel against the cost model's
ground truth (compute-bound -> s~1, memory-bound -> s~0)."""
from __future__ import annotations

import numpy as np

from benchmarks.scenarios import DEV, be_trainers, calibrated, fmt_csv, hp_services
from repro.core.costmodel import CostModel
from repro.core.lithos import run_alone
from repro.core.scheduler import LithOSConfig


def ground_truth_sensitivity(cost: CostModel, work, slices: int) -> float:
    """d(latency)/d(1/f) normalized — 1 if compute-bound, 0 if memory."""
    l_full = cost.latency(work, slices, 1.0)
    l_half = cost.latency(work, slices, 0.5)
    return max(0.0, min(1.5, (l_half / l_full - 1.0) / 1.0))


def run(quick: bool = False):
    rows = [fmt_csv("bench", "case", "value", "unit")]
    cost = CostModel(DEV)
    cases = {**{k: v for k, v in list(hp_services().items())[:2]},
             **{k: v for k, v in list(be_trainers().items())[:1 if quick else 3]}}
    for name, app in cases.items():
        app = calibrated(app, 0.5)
        res = run_alone(DEV, app, horizon=4.0 if quick else 8.0,
                        system="lithos",
                        lithos_config=LithOSConfig(dvfs=True, atomize=False))
        gov = res.policy.governor
        errs, senss = [], []
        for key, st in gov.stats.items():
            if not st.measured:
                continue
            recs = [r for r in res.records if r.task.key() == key]
            if not recs:
                continue
            gt = ground_truth_sensitivity(cost, recs[0].task.work,
                                          recs[0].slices)
            senss.append(st.s)
            errs.append(abs(st.s - gt))
        if senss:
            rows.append(fmt_csv("fig12", f"{name}/kernels_measured",
                                len(senss), "count"))
            rows.append(fmt_csv("fig12", f"{name}/mean_sensitivity",
                                f"{np.mean(senss):.3f}", "s"))
            rows.append(fmt_csv("fig12", f"{name}/mean_abs_fit_error",
                                f"{np.mean(errs):.3f}", "s"))
        rows.append(fmt_csv("fig12", f"{name}/aggregate_S",
                            f"{gov.aggregate_sensitivity():.3f}", "S"))
        rows.append(fmt_csv("fig12", f"{name}/f_target",
                            f"{gov.target_frequency():.2f}", "f/fmax"))
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    run()
