"""Fig 16 — hybrid inference/training multitenancy.

One HP inference service (latency SLO, ~80% target utilization per the
paper) stacked with one closed-loop BE training job.  Reports P99 service
latency normalized to solo and aggregate throughput (HP normalized to load
+ BE normalized to solo).  Paper: LithOS within 20% of ideal latency;
4.7x better than MPS; aggregate throughput 1.35x best SotA."""
from __future__ import annotations

import os
import sys
from dataclasses import replace
from itertools import product

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import numpy as np

from benchmarks.scenarios import (DEV, be_trainers, calibrated, fmt_csv,
                                  frac_throughput, hp_services)
from repro.core.lithos import evaluate, run_alone

SYSTEMS = ["lithos", "mps", "mig", "timeslice", "priority", "reef", "tgs",
           "orion"]


def combos(quick: bool):
    hp_pool = ["bert", "resnet"] if quick else ["llama3", "gptj", "bert",
                                                "retinanet", "resnet"]
    be_pool = ["llama_ft"] if quick else ["olmo_train", "xlstm_train",
                                          "rgemma_train", "moe_train",
                                          "whisper_train", "llama_ft"]
    out = list(product(hp_pool, be_pool))
    return out[:2] if quick else out[:6]


def run(quick: bool = False, json_out: bool = False):
    rows = [fmt_csv("bench", "system", "metric", "value", "unit")]
    horizon = 6.0 if quick else 12.0
    hp, be = hp_services(), be_trainers()
    agg = {s: [] for s in SYSTEMS}
    for hp_name, be_name in combos(quick):
        hpa = calibrated(replace(hp[hp_name], name="hp",
                                 quota_slices=DEV.n_slices), 0.8)
        bee = replace(be[be_name], name="be")
        solo_hp = run_alone(DEV, hpa, horizon=horizon, seed=21)
        solo_be = run_alone(DEV, bee, horizon=horizon, seed=21)
        p99_ideal = max(solo_hp.client("hp").p99, 1e-9)
        thr_be_alone = max(frac_throughput(solo_be, "be", horizon), 1e-9)
        for system in SYSTEMS:
            res = evaluate(system, DEV, [hpa, bee], horizon=horizon, seed=21)
            H, E = res.client("hp"), res.client("be")
            agg[system].append(dict(
                p99_norm=H.p99 / p99_ideal,
                hp_thr=H.throughput / max(hpa.rps, 1e-9),
                be_thr=frac_throughput(res, "be", horizon)
                / thr_be_alone,
                combo=f"{hp_name}+{be_name}"))
    for system in SYSTEMS:
        if not agg[system]:
            continue
        m = lambda k: float(np.mean([x[k] for x in agg[system]]))
        aggthr = m("hp_thr") + m("be_thr")
        rows.append(fmt_csv("fig16", system, "hp_p99_vs_ideal",
                            f"{m('p99_norm'):.2f}", "x"))
        rows.append(fmt_csv("fig16", system, "hp_throughput_vs_load",
                            f"{m('hp_thr'):.2f}", "x"))
        rows.append(fmt_csv("fig16", system, "be_throughput_vs_alone",
                            f"{m('be_thr'):.2f}", "x"))
        rows.append(fmt_csv("fig16", system, "aggregate_throughput",
                            f"{aggthr:.2f}", "x"))
    g = lambda s, k: float(np.mean([x[k] for x in agg[s]]))
    if agg["lithos"] and agg["mps"]:
        rows.append(fmt_csv("fig16", "derived", "mps_p99_over_lithos",
                            f"{g('mps','p99_norm')/g('lithos','p99_norm'):.2f}",
                            "x  (paper: 4.7x)"))
        rows.append(fmt_csv("fig16", "derived", "lithos_p99_vs_ideal",
                            f"{g('lithos','p99_norm'):.2f}",
                            "x  (paper: ~1.2x ideal)"))
        sotas = [s for s in SYSTEMS if s != "lithos" and agg[s]]
        best = min(sotas, key=lambda s: g(s, "p99_norm"))
        agg_ratio = ((g("lithos", "hp_thr") + g("lithos", "be_thr")) /
                     max(g(best, "hp_thr") + g(best, "be_thr"), 1e-9))
        rows.append(fmt_csv("fig16", "derived",
                            f"agg_throughput_vs_best_sota({best})",
                            f"{agg_ratio:.2f}", "x  (paper: 1.35x vs TGS)"))
    for r in rows:
        print(r)
    if json_out:
        from benchmarks._persist import csv_rows_to_results, write_json
        write_json("hybrid_stacking", csv_rows_to_results(rows),
                   {"horizon_s": horizon, "quick": quick, "seed": 21,
                    "systems": SYSTEMS,
                    "combos": [f"{h}+{b}" for h, b in combos(quick)],
                    "device": "a100_like"})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 combos, short horizon")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_HYBRID_STACKING.json")
    args = ap.parse_args()
    run(quick=args.smoke, json_out=args.json)
