"""Fig 10 — P99 kernel latency vs training batch size / LLM prompt length.

Derived from the workload compiler's kernel traces on the A100-calibrated
device: the paper's motivation (training batches and long prompts produce
multi-millisecond kernels that cause HoL blocking) must emerge from our
first-principles cost model."""
from __future__ import annotations

import numpy as np

from benchmarks.scenarios import DEV, fmt_csv
from repro.configs.registry import get_config
from repro.core.costmodel import CostModel
from repro.core.workloads import (decode_step_trace, fuse_trace,
                                  prefill_trace, train_step_trace)

TRAIN_ARCHS = ["olmo-1b", "xlstm-1.3b", "recurrentgemma-9b",
               "qwen2-moe-a2.7b", "llama3-8b"]
BATCHES = [2, 8, 16, 32]
PROMPTS = {"S": 512, "M": 2048, "L": 8192}


def kernel_p99(ops, cost: CostModel, fusion: int = 4) -> float:
    lats = [cost.latency(op.work(), DEV.n_slices)
            for op in fuse_trace(ops, fusion)]
    return float(np.percentile(lats, 99))


def run(quick: bool = False):
    cost = CostModel(DEV)
    rows = [fmt_csv("bench", "case", "value", "unit")]
    print("# Fig 10(a): P99 kernel latency vs train batch size")
    for arch in TRAIN_ARCHS:
        cfg = get_config(arch)
        for b in BATCHES:
            p99 = kernel_p99(train_step_trace(cfg, b, 2048), cost)
            rows.append(fmt_csv("fig10a", f"{arch}/bs{b}",
                                f"{p99*1e3:.3f}", "ms_p99_kernel"))
    print("# Fig 10(b): P99 kernel latency vs LLM prompt length")
    for name, S in PROMPTS.items():
        cfg = get_config("llama3-8b")
        p99_pre = kernel_p99(prefill_trace(cfg, 1, S), cost, fusion=6)
        p99_dec = kernel_p99(decode_step_trace(cfg, 1, S), cost, fusion=6)
        rows.append(fmt_csv("fig10b", f"llama3-8b/prefill_{name}",
                            f"{p99_pre*1e3:.3f}", "ms_p99_kernel"))
        rows.append(fmt_csv("fig10b", f"llama3-8b/decode_{name}",
                            f"{p99_dec*1e3:.3f}", "ms_p99_kernel"))
    for r in rows:
        print(r)
    # paper claim check: multi-ms kernels at large batch; growth with batch
    cfg = get_config("llama3-8b")
    small = kernel_p99(train_step_trace(cfg, BATCHES[0], 2048), cost)
    big = kernel_p99(train_step_trace(cfg, BATCHES[-1], 2048), cost)
    print(fmt_csv("fig10a", "derived/llama_growth",
                  f"{big/small:.2f}", "x_p99_growth"))
    assert big > small
    return rows


if __name__ == "__main__":
    run()
