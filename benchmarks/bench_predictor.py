"""§7.4 — latency-prediction accuracy.

Runs the inference-inference and inference-training stacks under full
LithOS and reports misprediction rates (|err| > 50 us) and error tails for
HP and BE work separately.  Paper: HP 0.9%/0.38%, BE 14%/11%; P99 error
49/31 us."""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.scenarios import DEV, be_trainers, calibrated, fmt_csv, hp_services
from repro.core.lithos import evaluate
from repro.core.types import Priority

THRESH = 50e-6


def accuracy(pred_log, prio):
    pairs = [(p, a) for p, a, pr in pred_log if pr == int(prio)]
    if not pairs:
        return float("nan"), float("nan")
    errs = [abs(p - a) for p, a in pairs]
    mis = float(np.mean([e > THRESH for e in errs]))
    p99 = float(np.percentile(errs, 99))
    return mis, p99


def run(quick: bool = False):
    rows = [fmt_csv("bench", "env", "metric", "value", "unit")]
    horizon = 6.0 if quick else 12.0
    hp = hp_services()
    envs = {
        "inf-inf": [
            calibrated(replace(hp["resnet"], name="hpA",
                               quota_slices=40), 0.35),
            calibrated(replace(hp["bert"], name="hpB", quota_slices=14),
                       0.2),
            replace(hp["gptj"], name="be", rps=0.0, quota_slices=0,
                    priority=Priority.BEST_EFFORT),
        ],
        "inf-train": [
            calibrated(replace(hp["bert"], name="hp",
                               quota_slices=DEV.n_slices), 0.7),
            replace(be_trainers()["llama_ft"], name="be"),
        ],
    }
    for env, apps in envs.items():
        res = evaluate("lithos", DEV, apps, horizon=horizon, seed=71)
        log = res.policy.pred_log
        for label, prio in (("hp", Priority.HIGH),
                            ("be", Priority.BEST_EFFORT)):
            mis, p99 = accuracy(log, prio)
            rows.append(fmt_csv("pred", env, f"{label}_misprediction",
                                f"{mis*100:.2f}", "%"))
            rows.append(fmt_csv("pred", env, f"{label}_err_p99",
                                f"{p99*1e6:.1f}", "us"))
        rows.append(fmt_csv("pred", env, "n_predictions", len(log), "count"))
    for r in rows:
        print(r)
    print(fmt_csv("pred", "derived", "paper_hp_rates", "0.9/0.38", "%"))
    return rows


if __name__ == "__main__":
    run()
