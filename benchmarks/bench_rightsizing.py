"""Fig 17 — hardware right-sizing capacity savings at slip 1.1.

Each workload runs solo with and without right-sizing; savings = the drop
in the time-weighted average of allocated slices.  Paper: mean ~26%
(up to 51%) capacity saved for a <=4% P99/throughput cost."""
from __future__ import annotations

import os
import sys

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import numpy as np

from benchmarks.scenarios import (DEV, be_trainers, calibrated,
                                  calibrated_solo_run, fmt_csv, hp_services)
from repro.core.lithos import run_alone
from repro.core.scheduler import LithOSConfig


def slice_seconds(res, name):
    return max(res.client(name).slice_seconds, 1e-9)


def run(quick: bool = False, json_out: bool = False):
    rows = [fmt_csv("bench", "case", "metric", "value", "unit")]
    cases = {**hp_services(), **be_trainers()}
    if quick:
        cases = {k: cases[k] for k in ["resnet", "llama3", "llama_ft"]}
    horizon = 10.0 if quick else 20.0
    warmup = 0.4            # probes/calibration happen early; measure steady
    savings, p99_costs, thr_costs = [], [], []
    for name, app in cases.items():
        app = calibrated(app, 0.5)
        # status-quo baseline: every kernel at the job's full allocation
        base = run_alone(DEV, app, horizon=horizon, seed=31,
                         lithos_config=LithOSConfig(
                             rightsize=False, occupancy_filter=False))
        rs = calibrated_solo_run(
            app, LithOSConfig(rightsize=True, slip=1.1),
            horizon=horizon, cal_horizon=horizon, seed=31)
        used_base = slice_seconds(base, app.name)
        used_rs = slice_seconds(rs, app.name)
        save = 1.0 - used_rs / used_base
        savings.append(save)
        rows.append(fmt_csv("fig17", name, "capacity_savings",
                            f"{save*100:.1f}", "%"))
        if app.kind != "train":
            b99 = base.client(app.name).p(99, warmup)
            r99 = rs.client(app.name).p(99, warmup)
            if np.isfinite(b99) and np.isfinite(r99) and b99 > 0:
                p99_costs.append(r99 / b99 - 1.0)
                rows.append(fmt_csv("fig17", name, "p99_cost",
                                    f"{(r99/b99-1)*100:.1f}", "%"))
        tb = base.client(app.name).throughput
        tr = rs.client(app.name).throughput
        if tb > 0:
            thr_costs.append(1.0 - tr / tb)
            rows.append(fmt_csv("fig17", name, "throughput_cost",
                                f"{(1-tr/tb)*100:.1f}", "%"))
    rows.append(fmt_csv("fig17", "derived", "mean_capacity_savings",
                        f"{np.mean(savings)*100:.1f}",
                        "%  (paper: ~26%, max 51%)"))
    if p99_costs:
        rows.append(fmt_csv("fig17", "derived", "mean_p99_cost",
                            f"{np.mean(p99_costs)*100:.1f}",
                            "%  (paper: ~4%)"))
    if thr_costs:
        rows.append(fmt_csv("fig17", "derived", "mean_throughput_cost",
                            f"{np.mean(thr_costs)*100:.1f}",
                            "%  (paper: ~4%)"))
    for r in rows:
        print(r)
    if json_out:
        from benchmarks._persist import csv_rows_to_results, write_json
        write_json("rightsizing", csv_rows_to_results(rows),
                   {"horizon_s": horizon, "quick": quick, "seed": 31,
                    "slip": 1.1, "cases": sorted(cases),
                    "device": "a100_like"})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3 workloads, short horizon")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_RIGHTSIZING.json")
    args = ap.parse_args()
    run(quick=args.smoke, json_out=args.json)
