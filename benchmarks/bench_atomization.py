"""Fig 20 — atomization case study: P95 HP latency vs BE batch size and
BE sequence length.

HP BERT-stand-in inference collocated with (a) BE training at growing batch
sizes, (b) BE LLM inference at growing prompt lengths.  Compared: full
LithOS, LithOS w/o atomization, REEF.  Paper: LithOS beats REEF 6.5x/3.9x;
atomization itself contributes 2x/1.3x."""
from __future__ import annotations

from dataclasses import replace

import numpy as np

from benchmarks.scenarios import DEV, be_trainers, calibrated, fmt_csv, hp_services
from repro.core.lithos import evaluate, run_alone
from repro.core.scheduler import LithOSConfig

SYSTEMS = {
    "lithos": LithOSConfig(atomize=True),
    "lithos_no_atom": LithOSConfig(atomize=False),
}


def run(quick: bool = False):
    rows = [fmt_csv("bench", "case", "system", "p95_ms", "vs_ideal")]
    horizon = 6.0 if quick else 10.0
    hp = calibrated(replace(hp_services()["bert"], name="hp",
                            quota_slices=DEV.n_slices), 0.6)
    ideal = max(run_alone(DEV, hp, horizon=horizon, seed=61)
                .client("hp").p(95), 1e-9)

    batches = [8, 32] if quick else [4, 16, 64]
    for b in batches:
        be = replace(be_trainers()["llama_ft"], name="be", train_batch=b)
        for sysname, cfgv in SYSTEMS.items():
            res = evaluate("lithos", DEV, [hp, be], horizon=horizon,
                           seed=61, lithos_config=cfgv)
            p95 = res.client("hp").p(95)
            rows.append(fmt_csv("fig20a", f"train_bs{b}", sysname,
                                f"{p95*1e3:.2f}", f"{p95/ideal:.2f}x"))
        res = evaluate("reef", DEV, [hp, be], horizon=horizon, seed=61)
        p95 = res.client("hp").p(95)
        rows.append(fmt_csv("fig20a", f"train_bs{b}", "reef",
                            f"{p95*1e3:.2f}", f"{p95/ideal:.2f}x"))

    seqs = [2048] if quick else [512, 2048, 8192]
    for s in seqs:
        be = replace(hp_services()["llama3"], name="be", rps=0.0,
                     quota_slices=0, prompt_mix=((s, 1.0),),
                     priority=__import__("repro.core.types",
                                         fromlist=["Priority"]
                                         ).Priority.BEST_EFFORT)
        for sysname, cfgv in SYSTEMS.items():
            res = evaluate("lithos", DEV, [hp, be], horizon=horizon,
                           seed=62, lithos_config=cfgv)
            p95 = res.client("hp").p(95)
            rows.append(fmt_csv("fig20b", f"seq{s}", sysname,
                                f"{p95*1e3:.2f}", f"{p95/ideal:.2f}x"))
        res = evaluate("reef", DEV, [hp, be], horizon=horizon, seed=62)
        p95 = res.client("hp").p(95)
        rows.append(fmt_csv("fig20b", f"seq{s}", "reef",
                            f"{p95*1e3:.2f}", f"{p95/ideal:.2f}x"))
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    run()
