"""Shared persistence for benchmark results: one JSON document per bench,
written next to the repo root (committed for the headline runs, uploaded as
a CI artifact for the smoke runs).

Schema (one top-level object per file):

    {
      "bench": "sim_throughput",
      "git_rev": "<short sha or 'unknown'>",
      "timestamp": "<iso8601 utc>",
      "host": {"python": "3.10.16", "numpy": "1.26.4"},
      "results": [...bench-specific rows...],
      "meta": {...bench-specific scenario metadata...}
    }

Use :func:`write_json` from a bench module; use :func:`csv_rows_to_results`
to wrap the legacy ``fmt_csv`` row lists benches already print.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
from datetime import datetime, timezone

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def git_rev(root: str = ROOT) -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=root, capture_output=True, text=True,
                             timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def host_info() -> dict:
    info = {"python": platform.python_version()}
    try:
        import numpy
        info["numpy"] = numpy.__version__
    except ImportError:                      # pragma: no cover
        pass
    return info


def write_json(bench: str, results, meta: dict | None = None,
               path: str | None = None) -> str:
    """Serialize one bench's results; returns the path written."""
    doc = {
        "bench": bench,
        "git_rev": git_rev(),
        "timestamp": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "host": host_info(),
        "results": results,
        "meta": meta or {},
    }
    if path is None:
        path = os.path.join(ROOT, f"BENCH_{bench.upper()}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"[{bench}] wrote {path}", file=sys.stderr)
    return path


def csv_rows_to_results(rows: list[str]) -> list[dict]:
    """Convert a bench's printed CSV rows (header row first) into a list of
    dicts keyed by the header columns — the adapter that lets every legacy
    ``fmt_csv`` bench persist through :func:`write_json` unchanged."""
    if not rows:
        return []
    header = rows[0].split(",")
    out = []
    for row in rows[1:]:
        cols = row.split(",")
        # tolerate value cells containing commas (none today, but cheap)
        if len(cols) > len(header):
            cols = cols[:len(header) - 1] + [",".join(cols[len(header) - 1:])]
        out.append(dict(zip(header, cols)))
    return out
