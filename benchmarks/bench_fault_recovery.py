"""Fault-recovery benchmark: device loss under load, measured end to end.

Three questions matter when a device dies under a live mix and this bench
answers all of them against the real code paths (no mocks):

* **recovery latency** — sim time from the ``device_dead`` event to the
  first post-fault completion of every evacuated tenant (HP tenants move
  via the elastic re-own path, BE via plain migration);
* **post-fault throughput vs. a surviving-capacity oracle** — completions
  in the post-fault window, divided by the same mix run on the surviving
  devices alone (same non-fatal faults, no death, no evacuation cost).
  The oracle is what a clairvoyant scheduler that never placed anything
  on the doomed device could deliver; the ratio is the price of actually
  recovering;
* **HP SLO cleanliness** — the post-fault window split into sub-windows,
  counting how many are free of HP completions slower than 3x the
  oracle's p95 (evacuation pain should be a spike, not a new steady
  state);
* **no job lost** — the control-plane arm kills a device under a live
  daemon and proves, by journal replay, that every submitted job reaches
  DONE exactly once (fault record present, recoveries journaled).

Usage:
    PYTHONPATH=src python benchmarks/bench_fault_recovery.py [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from _persist import write_json                              # noqa: E402
from repro.configs.registry import get_config                # noqa: E402
from repro.core.lithos import evaluate                       # noqa: E402
from repro.core.types import (DeviceSpec, FaultEvent,        # noqa: E402
                              FaultPlan, NodeConfig, NodeSpec, Priority)
from repro.core.workloads import AppSpec                     # noqa: E402
from repro.ctl import store                                  # noqa: E402
from repro.ctl.daemon import ControlPlane, DaemonConfig      # noqa: E402
from repro.ctl.state import JobState                         # noqa: E402

PRESETS = {
    "full": {"horizon": 6.0, "n_devices": 3, "n_ctl_jobs": 4},
    "smoke": {"horizon": 2.0, "n_devices": 3, "n_ctl_jobs": 3},
}

OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")
DEV = DeviceSpec.a100_like()


def _apps(n_devices: int):
    """One HP serving tenant + one BE trainer per device; device 1 gets a
    continuous-batching LLM tenant so the KV floor steers its evacuation."""
    apps, placement = [], []
    for d in range(n_devices):
        if d == 1:
            hp = AppSpec(f"hp{d}", OLMO, "llm_continuous",
                         priority=Priority.HIGH, rps=30.0, max_batch=4,
                         decode_tokens=8, fusion=8,
                         prompt_mix=((256, 0.7), (1024, 0.3)), seed=d)
        else:
            hp = AppSpec(f"hp{d}", OLMO, "fwd_infer", priority=Priority.HIGH,
                         rps=25.0, prompt_mix=((128, 1.0),), batch=4,
                         fusion=8, seed=d)
        be = AppSpec(f"be{d}", LLAMA, "train", priority=Priority.BEST_EFFORT,
                     train_batch=2, train_seq=1024, fusion=8, seed=10 + d)
        apps += [hp, be]
        placement += [d, d]
    return apps, placement


def _fault_plans(n_devices: int, horizon: float):
    """Device 0 dies mid-run; survivors take an ECC retirement and a
    transient stall.  The oracle plan is the survivor faults re-indexed
    onto the (n-1)-device oracle node."""
    t_dead = 0.4 * horizon
    faulted = FaultPlan(events=(
        FaultEvent(t=t_dead, kind="device_dead", member=0),
        FaultEvent(t=0.5 * horizon, kind="slice_retired", member=1,
                   slice_id=3),
        FaultEvent(t=0.55 * horizon, kind="transient_stall",
                   member=min(2, n_devices - 1), duration=20e-3),
    ))
    oracle = FaultPlan(events=(
        FaultEvent(t=0.5 * horizon, kind="slice_retired", member=0,
                   slice_id=3),
        FaultEvent(t=0.55 * horizon, kind="transient_stall",
                   member=min(1, n_devices - 2), duration=20e-3),
    ))
    return t_dead, faulted, oracle


def bench_recovery(n_devices: int, horizon: float) -> list[dict]:
    apps, placement = _apps(n_devices)
    t_dead, plan, oracle_plan = _fault_plans(n_devices, horizon)
    ncfg = NodeConfig(migration=True, validate=True)

    res = evaluate("lithos", NodeSpec.uniform(n_devices, DEV), apps,
                   horizon=horizon, placement=list(placement),
                   node_config=ncfg, faults=plan)
    coord = res.coordinator
    assert coord.failed_members == {0}, coord.failed_members
    assert not coord.stranded, coord.stranded

    # oracle: the same mix, minus the doomed device, on the survivors only
    # (evacuees pre-placed where the real run eventually moved them)
    dst_of = {cid: coord.ledger.current[cid]
              for cid, d in enumerate(placement) if d == 0}
    oracle_placement = [dst_of.get(cid, d) - 1
                        for cid, d in enumerate(placement)]
    oracle = evaluate("lithos", NodeSpec.uniform(n_devices - 1, DEV), apps,
                      horizon=horizon, placement=oracle_placement,
                      node_config=ncfg, faults=oracle_plan)

    evacuated = sorted(cid for cid, d in enumerate(placement) if d == 0)
    rec_lats = []
    for cid in evacuated:
        post = [r.t_end for r in res.records
                if r.task.queue_id == cid and r.t_end > t_dead]
        assert post, f"evacuated client {cid} never completed after fault"
        rec_lats.append(min(post) - t_dead)

    post_f = sum(1 for r in res.records if r.t_end > t_dead)
    post_o = sum(1 for r in oracle.records if r.t_end > t_dead)
    ratio = post_f / post_o if post_o else float("nan")

    hp_cids = [cid for cid, a in enumerate(apps)
               if a.priority == Priority.HIGH]
    o_lats = [r.t_end - r.t_submit for r in oracle.records
              if r.task.queue_id in hp_cids and r.t_end > t_dead]
    thresh = 3.0 * float(np.percentile(o_lats, 95)) if o_lats else float("inf")
    n_win = 10
    edges = np.linspace(t_dead, horizon, n_win + 1)
    clean = 0
    for lo, hi in zip(edges[:-1], edges[1:]):
        bad = any(r.t_end - r.t_submit > thresh for r in res.records
                  if r.task.queue_id in hp_cids and lo < r.t_end <= hi)
        clean += not bad
    return [
        {"metric": "recovery_latency_s", "t_dead": round(t_dead, 3),
         "evacuated": len(evacuated),
         "max": round(max(rec_lats), 4),
         "mean": round(float(np.mean(rec_lats)), 4)},
        {"metric": "post_fault_throughput",
         "faulted_completions": post_f, "oracle_completions": post_o,
         "ratio_vs_oracle": round(ratio, 4)},
        {"metric": "hp_slo_windows", "windows": n_win,
         "violation_free": clean,
         "threshold_s": round(thresh, 4) if o_lats else None},
    ]


def bench_ctl_no_job_lost(n_jobs: int) -> dict:
    """Kill a device under a live daemon; prove by replay that every job
    reaches DONE exactly once on surviving capacity."""
    d = tempfile.mkdtemp(prefix="fault-bench-")
    try:
        plan = FaultPlan(events=(
            FaultEvent(t=0.5, kind="device_dead", member=0),))
        jids = [store.request_submit(
            d, {"kind": "serve", "rps": 30.0, "duration": 1.5,
                "priority": "hp", "quota_slices": 8, "name": f"svc{i}"})
            for i in range(n_jobs)]
        t0 = time.time()
        cp = ControlPlane(d, DaemonConfig(n_devices=2, fault_plan=plan,
                                          validate=True, poll_interval=0.0))
        cp.run(max_wall=120.0, exit_when_idle=True)
        wall = time.time() - t0
        jobs = store.replay(d)
        recs = store._read_records(os.path.join(d, store.JOURNAL))
        finishes = {jid: sum(1 for r in recs if r["job"] == jid
                             and r["event"] == "finish") for jid in jids}
        lost = [jid for jid in jids if jobs[jid].state is not JobState.DONE]
        dup = [jid for jid, n in finishes.items() if n != 1]
        faults = [r for r in recs if r["event"] == "fault"]
        assert not lost, lost
        assert not dup, dup
        assert len(faults) == 1 and faults[0]["device"] == 0
        recovered = sum(1 for jid in jids if jobs[jid].recoveries >= 1)
        assert recovered >= 1, "device death touched no job?"
        return {"metric": "ctl_no_job_lost", "jobs": n_jobs,
                "done": len(jids) - len(lost), "lost": len(lost),
                "duplicated": len(dup), "recovered": recovered,
                "fault_records": len(faults),
                "wall_s": round(wall, 3)}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small preset for CI")
    ap.add_argument("--json", action="store_true",
                    help="persist BENCH_FAULT_RECOVERY.json via _persist")
    args = ap.parse_args(argv)
    preset = PRESETS["smoke" if args.smoke else "full"]

    results = bench_recovery(preset["n_devices"], preset["horizon"])
    results.append(bench_ctl_no_job_lost(preset["n_ctl_jobs"]))
    for r in results:
        print(r)
    if not args.smoke:
        ratio = next(r for r in results
                     if r["metric"] == "post_fault_throughput")
        assert ratio["ratio_vs_oracle"] >= 0.9, ratio
    if args.json:
        write_json("fault_recovery", results,
                   meta={"preset": "smoke" if args.smoke else "full",
                         **preset})


if __name__ == "__main__":
    main()
