"""LLM-era stacking: steady continuous-batching decode vs prefill bursts.

The adversarial arm is the one "Towards Efficient and Practical GPU
Multitasking in the Era of LLM" (PAPERS.md) says breaks kernel-granular
multitasking: a latency-critical continuous-batching decode tenant (HP,
per-token TBT SLO, KV cache pinned on device) stacked with best-effort
prefill bursters (8k-token prompts, multi-ms compute-bound kernels — the
Fig 10b HoL-blockers).  LithOS atomizes the prefill kernels and keeps the
decode tenant's slices owned + memory-floored; the MPS-like baseline lets
decode iterations queue behind whole prefill kernels; MIG protects decode
but strands the partition.

Reported per system and arm:

* decode p99 TBT (per-iteration latency of the continuous tenant) and
  request p95 (arrival -> last token);
* prefill throughput vs running alone (fractional-progress counting);
* aggregate normalized throughput (mean of decode requests/s and BE
  throughput, each vs solo) — the "at equal-or-better throughput" check;
* KV-pressure occupancy: the decode tenant's peak KV bytes over device
  HBM.

Usage::

    python benchmarks/bench_llm_stacking.py [--smoke] [--json]
        [--min-events-per-sec N]

``--smoke`` is the CI preset (short horizon, one arm); the full run is
committed as BENCH_LLM_STACKING.json.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from dataclasses import replace

import numpy as np

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.scenarios import DEV, calibrated, fmt_csv, frac_throughput
from repro.configs.registry import get_config
from repro.core.lithos import evaluate, run_alone
from repro.core.types import Priority
from repro.core.workloads import AppSpec

SYSTEMS = ["lithos", "mps", "mig"]


def decode_tenant(target_util: float = 0.25) -> AppSpec:
    """Steady continuous-batching serving: short prompts, per-token SLO."""
    app = AppSpec("decode", get_config("llama3-8b"), "llm_continuous",
                  priority=Priority.HIGH,
                  quota_slices=DEV.n_slices // 2,
                  max_batch=8, decode_tokens=12, fusion=8,
                  prompt_mix=((512, 0.7), (2048, 0.3)), seed=3)
    return calibrated(app, target_util, slo_mult=6.0)


def prefill_bursters() -> list[AppSpec]:
    """Closed-loop BE prefill: 8k-token prompts at batch 4, fusion 16 —
    sustained multi-ms compute kernels (two streams, like a bulk
    summarization/embedding backfill)."""
    base = AppSpec("prefill", get_config("qwen2-moe-a2.7b"), "llm_prefill",
                   priority=Priority.BEST_EFFORT, quota_slices=0, rps=0.0,
                   batch=4, fusion=16, prompt_mix=((8192, 1.0),), seed=41)
    return [base, replace(base, name="prefill2", seed=97)]


def be_trainer() -> AppSpec:
    return AppSpec("train", get_config("llama3-8b"), "train",
                   priority=Priority.BEST_EFFORT, train_batch=2,
                   train_seq=2048, fusion=10, seed=55)


def arms(quick: bool) -> dict[str, list[AppSpec]]:
    cont = decode_tenant()
    out = {"adversarial": [cont] + prefill_bursters()}
    if not quick:
        out["steady"] = [cont, be_trainer()]
    return out


def _cont_stats(res, horizon: float):
    c = res.client("decode")
    tbt = c.latencies
    req = c.req_latencies or []
    kv_frac = c.kv_peak_bytes / (DEV.hbm_capacity * DEV.n_slices)
    return {
        "tbt_p50_ms": float(np.percentile(tbt, 50)) * 1e3 if tbt else 0.0,
        "tbt_p99_ms": float(np.percentile(tbt, 99)) * 1e3 if tbt else 0.0,
        "req_p95_ms": float(np.percentile(req, 95)) * 1e3 if req else 0.0,
        "req_per_s": len(req) / horizon,
        "kv_occupancy": kv_frac,
    }


def run(quick: bool = False, json_out: bool = False,
        min_events_per_sec: float = 0.0) -> list[str]:
    horizon = 2.0 if quick else 10.0
    seed = 11
    rows = [fmt_csv("bench", "arm", "system", "metric", "value", "unit")]
    results = []
    total_events = 0
    t0 = time.perf_counter()
    for arm, apps in arms(quick).items():
        cont = apps[0]
        # solo normalization baselines
        solo_cont = run_alone(DEV, cont, horizon=horizon, seed=seed)
        solo_req = max(_cont_stats(solo_cont, horizon)["req_per_s"], 1e-9)
        be_names = [a.name for a in apps[1:]]
        solo_be = {}
        for a in apps[1:]:
            r = run_alone(DEV, a, horizon=horizon, seed=seed)
            solo_be[a.name] = max(frac_throughput(r, a.name, horizon), 1e-9)
        for system in SYSTEMS:
            res = evaluate(system, DEV, apps, horizon=horizon, seed=seed)
            total_events += len(res.records)
            s = _cont_stats(res, horizon)
            be_thr = float(np.mean(
                [frac_throughput(res, n, horizon) / solo_be[n]
                 for n in be_names]))
            decode_thr = s["req_per_s"] / solo_req
            agg_thr = (decode_thr + be_thr) / 2.0
            row = dict(arm=arm, system=system, **s,
                       decode_thr_vs_alone=decode_thr,
                       be_thr_vs_alone=be_thr,
                       agg_thr_vs_alone=agg_thr)
            results.append(row)
            for k, unit in (("tbt_p50_ms", "ms"), ("tbt_p99_ms", "ms"),
                            ("req_p95_ms", "ms"), ("kv_occupancy", "frac"),
                            ("decode_thr_vs_alone", "x"),
                            ("be_thr_vs_alone", "x"),
                            ("agg_thr_vs_alone", "x")):
                rows.append(fmt_csv("llm_stacking", arm, system, k,
                                    f"{row[k]:.4f}", unit))
    wall = time.perf_counter() - t0
    ev_per_sec = total_events / max(wall, 1e-9)
    rows.append(fmt_csv("llm_stacking", "all", "all", "events_per_sec",
                        f"{ev_per_sec:.0f}", "1/s"))

    # derived headline ratios (adversarial arm)
    by = {(r["arm"], r["system"]): r for r in results}
    adv_l, adv_m = by[("adversarial", "lithos")], by[("adversarial", "mps")]
    tbt_ratio = adv_m["tbt_p99_ms"] / max(adv_l["tbt_p99_ms"], 1e-9)
    rows.append(fmt_csv("llm_stacking", "adversarial", "derived",
                        "mps_p99_tbt_over_lithos", f"{tbt_ratio:.2f}",
                        "x  (claim: >= 2x)"))
    thr_delta = adv_l["agg_thr_vs_alone"] - adv_m["agg_thr_vs_alone"]
    rows.append(fmt_csv("llm_stacking", "adversarial", "derived",
                        "lithos_agg_thr_minus_mps", f"{thr_delta:+.4f}",
                        "x  (claim: >= 0)"))
    for r in rows:
        print(r)
    if json_out:
        from benchmarks._persist import write_json
        write_json("llm_stacking", results,
                   {"horizon_s": horizon, "quick": quick, "seed": seed,
                    "systems": SYSTEMS,
                    "events_per_sec": ev_per_sec,
                    "mps_p99_tbt_over_lithos": tbt_ratio,
                    "lithos_agg_thr_minus_mps": thr_delta})
    if min_events_per_sec and ev_per_sec < min_events_per_sec:
        print(f"FAIL: {ev_per_sec:.0f} events/sec < floor "
              f"{min_events_per_sec:.0f}", file=sys.stderr)
        sys.exit(1)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: short horizon, adversarial arm only")
    ap.add_argument("--json", action="store_true",
                    help="persist BENCH_LLM_STACKING.json via _persist")
    ap.add_argument("--min-events-per-sec", type=float, default=0.0)
    args = ap.parse_args(argv)
    run(quick=args.smoke, json_out=args.json,
        min_events_per_sec=args.min_events_per_sec)


if __name__ == "__main__":
    main()
