"""TPU-native atomization overhead — Pallas kernel atom-count sweep.

Times the XLA-compiled (CPU backend) atomized matmul at increasing atom
counts: correctness is identical by construction (tests), and the measured
launch/dispatch overhead trend is the structural cost the LithOS atomizer's
adaptive atom_duration bounds (§4.4).  On TPU the per-atom overhead is one
extra pallas_call launch (~us); the same trend holds."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.scenarios import fmt_csv


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(quick: bool = False):
    rows = [fmt_csv("bench", "case", "value", "unit")]
    M = N = K = 512 if quick else 1024
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (M, K), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)

    # Reference: single fused XLA dot
    ref = jax.jit(lambda a, b: a @ b)
    t_ref = _time(ref, a, b)
    rows.append(fmt_csv("pallas", "xla_dot", f"{t_ref*1e6:.0f}", "us"))

    # Atomized schedules: the same matmul as n sequential atom dispatches
    # (jitted jnp equivalent of the Pallas atom schedule — the Pallas
    # kernels themselves are validated in interpret mode in tests/)
    from repro.kernels.atom_matmul.ops import atom_ranges
    from repro.kernels.atom_matmul.kernel import tile_count

    bm = bn = 256
    total = tile_count(M, N, bm, bn)
    nn = N // bn

    for n_atoms in ([1, 4] if quick else [1, 2, 4, 8, 16]):
        ranges = atom_ranges(total, n_atoms)

        @jax.jit
        def atomized(a, b):
            c = jnp.zeros((M, N), a.dtype)
            for start, ln in ranges:
                for t in range(start, start + ln):
                    mi, ni = t // nn, t % nn
                    tile = jax.lax.dynamic_slice(
                        a, (mi * bm, 0), (bm, K)) @ jax.lax.dynamic_slice(
                        b, (0, ni * bn), (K, bn))
                    c = jax.lax.dynamic_update_slice(c, tile,
                                                     (mi * bm, ni * bn))
            return c

        t = _time(atomized, a, b)
        err = float(jnp.abs(atomized(a, b) - ref(a, b)).max())
        rows.append(fmt_csv("pallas", f"atoms_{n_atoms}",
                            f"{t*1e6:.0f}", f"us  overhead={t/t_ref:.2f}x "
                            f"maxerr={err:.1e}"))
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    run()
