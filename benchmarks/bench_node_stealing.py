"""Cross-device TPC stealing: node-level lending vs static placement.

Quantifies the NodeCoordinator's stolen-capacity throughput gain on the
ROADMAP's router-quality adversarial mixes — placements a static router gets
wrong because load materialized after the placement decision:

  * ``idle_saturated``  — every tenant pinned on device 0, device 1 idle
    (burst arrival at one service / stale forecast).  The canonical
    saturated-D' + idle-D shape of §4.3 scaled across devices.
  * ``skewed``          — heavy HP + two BE trainers on device 0, one light
    HP service on device 1 (imbalanced but not empty: stealing must not
    regress the light service's SLO).

For each mix it runs lithos with ``migration=off`` (static baseline) and
with the lending protocol on, and reports per-tenant HP P99/SLO attainment,
BE fractional throughput, node utilization, migration count and donated
device-seconds.  Headline: >= 1.2x aggregate BE throughput on the
idle+saturated mix with zero HP SLO regressions.

    PYTHONPATH=src python benchmarks/bench_node_stealing.py [--smoke]
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.scenarios import (DEV, be_trainers, calibrated, fmt_csv,
                                  frac_throughput, hp_services)
from dataclasses import replace

from repro.core.lithos import evaluate
from repro.core.types import NodeConfig, NodeSpec, Priority

STEAL = NodeConfig(migration=True, epoch=0.25, migration_cost=0.05,
                   cooldown=2.0, free_hi=0.5, free_lo=0.2, hp_depth_hi=3)
STATIC = NodeConfig(migration=False)


def mixes():
    hp = hp_services()
    be = be_trainers()
    hp0 = calibrated(replace(hp["resnet"], name="hp0"), 0.5, device=DEV,
                     slo_mult=4.0)
    hp1 = calibrated(replace(hp["bert"], name="hp1"), 0.15, device=DEV,
                     slo_mult=4.0)
    be0 = replace(be["olmo_train"], name="be0", train_batch=2, train_seq=512)
    be1 = replace(be0, name="be1")
    return {
        # everything lands on device 0; device 1 has no tenants at all
        "idle_saturated": ([hp0, be0, be1], [0, 0, 0]),
        # device 1 hosts a light HP service: a lender, but with an SLO to keep
        "skewed": ([hp0, be0, be1, hp1], [0, 0, 0, 1]),
    }


def run_mix(tag, apps, placement, node, horizon, seed, rows):
    out = {}
    for mode, cfg in (("static", STATIC), ("stealing", STEAL)):
        res = evaluate("lithos", node, apps, horizon=horizon, seed=seed,
                       placement=placement, node_config=cfg)
        hp_slo, be_thr = [], 0.0
        for app in apps:
            cm = res.client(app.name)
            if app.priority == Priority.HIGH:
                slo = cm.slo_attainment(app.slo_latency)
                hp_slo.append((app.name, slo))
                rows.append(fmt_csv(tag, mode, f"{app.name}_p99",
                                    f"{cm.p99 * 1e3:.2f}", "ms"))
                rows.append(fmt_csv(tag, mode, f"{app.name}_slo",
                                    f"{slo * 100:.1f}", "%"))
            else:
                thr = frac_throughput(res, app.name, horizon)
                be_thr += thr
                rows.append(fmt_csv(tag, mode, f"{app.name}_throughput",
                                    f"{thr:.3f}", "jobs/s"))
        rows.append(fmt_csv(tag, mode, "agg_be_throughput",
                            f"{be_thr:.3f}", "jobs/s"))
        rows.append(fmt_csv(tag, mode, "node_utilization",
                            f"{res.utilization * 100:.1f}", "%"))
        rows.append(fmt_csv(tag, mode, "migrations", res.migrations, "n"))
        if res.ledger is not None:
            rows.append(fmt_csv(tag, mode, "donated_device_seconds",
                                f"{res.ledger.donated_seconds(horizon):.2f}",
                                "s"))
        out[mode] = (be_thr, dict(hp_slo))
    gain = out["stealing"][0] / max(out["static"][0], 1e-9)
    rows.append(fmt_csv(tag, "-", "be_throughput_gain", f"{gain:.2f}", "x"))
    regressed = [n for n, s in out["stealing"][1].items()
                 if s < out["static"][1][n] - 1e-9]
    rows.append(fmt_csv(tag, "-", "hp_slo_regressions",
                        "|".join(regressed) or "none", ""))
    return gain, regressed


def run(quick: bool = False, json_out: bool = False):
    rows = [fmt_csv("mix", "mode", "metric", "value", "unit")]
    horizon = 3.0 if quick else 10.0
    node = NodeSpec.uniform(2, DEV)
    failures = []
    for tag, (apps, placement) in mixes().items():
        gain, regressed = run_mix(tag, apps, placement, node, horizon, 17,
                                  rows)
        if tag == "idle_saturated" and gain < 1.2:
            failures.append(f"{tag}: BE gain {gain:.2f}x < 1.2x")
        if regressed:
            failures.append(f"{tag}: HP SLO regressed for {regressed}")
    for r in rows:
        print(r)
    if json_out:
        from benchmarks._persist import csv_rows_to_results, write_json
        write_json("node_stealing", csv_rows_to_results(rows),
                   {"horizon_s": horizon, "quick": quick, "seed": 17,
                    "node": "2x a100_like"})
    if failures:
        raise RuntimeError("; ".join(failures))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="short horizons")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_NODE_STEALING.json")
    args = ap.parse_args()
    run(quick=args.smoke, json_out=args.json)
