"""Simulator-core throughput: vectorized engine vs scalar reference.

The scenario is a single A100-like device packed with open-loop inference
tenants (olmo-1b fwd_infer, fusion=64 -> 3 kernels/request) calibrated to
~0.85 aggregate offered utilization, so the event stream mixes arrivals,
dispatches and completions at scale.  Presets:

  * ``trace1m`` — 320 tenants, ~1e6 requests (the headline trajectory
    committed in BENCH_SIM.json; target >= 10x events/sec vec vs ref)
  * ``smoke``   — 24 tenants, ~6k requests (CI perf-smoke; asserts an
    absolute vec events/sec floor)

Both engines run with ``collect_records=False`` (the lean-memory mode) so
the comparison measures the core, not record retention.  Because the
reference engine is O(clients) per event, running it over the full 1M
trace takes hours; ``--ref-fraction`` runs the reference over a leading
fraction of the horizon instead.  events/sec is a *rate*, so no
extrapolation is applied — the fraction just bounds wall time, and the
fraction used is recorded in the JSON.

    PYTHONPATH=src python benchmarks/bench_sim_throughput.py \
        [--preset trace1m|smoke] [--ref-fraction F] [--engines vec,ref]
        [--min-events-per-sec N] [--assert-speedup X] [--json]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __package__ in (None, ""):               # direct invocation
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.scenarios import DEV, fmt_csv
from repro.configs.registry import get_config
from repro.core import types as T
from repro.core.lithos import make_policy
from repro.core.simulator import make_simulator
from repro.core.types import Priority
from repro.core.workloads import AppSpec, mean_demand

PRESETS = {
    # name: (n_clients, target_total_requests)
    "trace1m": (320, 1_000_000),
    "smoke": (24, 6_000),
}
TOTAL_UTIL = 0.85


def build_apps(n_clients: int, total_requests: int):
    """N identical open-loop inference tenants; returns (apps, horizon)."""
    cfg = get_config("olmo-1b")
    proto = AppSpec("t0", cfg, "fwd_infer", priority=Priority.HIGH,
                    batch=2, fusion=64, prompt_mix=((128, 1.0),))
    demand = mean_demand(proto, DEV)        # device-seconds per request
    total_rps = TOTAL_UTIL / demand
    horizon = total_requests / total_rps
    rps = total_rps / n_clients
    apps = [AppSpec(f"t{i}", cfg, "fwd_infer", priority=Priority.HIGH,
                    batch=2, fusion=64, prompt_mix=((128, 1.0),),
                    rps=rps, seed=i)
            for i in range(n_clients)]
    return apps, horizon


def run_engine(engine: str, apps, horizon: float, seed: int = 0):
    T.reset_kernel_ids()
    policy = make_policy("lithos", DEV, apps)
    sim = make_simulator(DEV, apps, policy, engine=engine, horizon=horizon,
                         seed=seed, collect_records=False)
    t0 = time.perf_counter()
    sim.run()
    wall = time.perf_counter() - t0
    jobs = sum(len(c.completed) for c in sim.clients)
    return {
        "engine": engine,
        "horizon_s": horizon,
        "wall_s": round(wall, 3),
        "events": sim.events,
        "events_per_sec": round(sim.events / wall, 1),
        "jobs_completed": jobs,
        "energy": sim.energy,
    }


def run(quick: bool = False, preset: str | None = None,
        ref_fraction: float | None = None, engines=("vec", "ref"),
        min_events_per_sec: float = 0.0, assert_speedup: float = 0.0,
        json_out: bool = False):
    preset = preset or ("smoke" if quick else "trace1m")
    n_clients, total_requests = PRESETS[preset]
    if ref_fraction is None:
        ref_fraction = 0.02 if preset == "trace1m" else 1.0
    apps, horizon = build_apps(n_clients, total_requests)

    rows = [fmt_csv("bench", "engine", "metric", "value", "unit")]
    results = []
    for engine in engines:
        h = horizon * (ref_fraction if engine == "ref" else 1.0)
        r = run_engine(engine, apps, h)
        r["horizon_fraction"] = ref_fraction if engine == "ref" else 1.0
        results.append(r)
        for metric, unit in (("events", "n"), ("wall_s", "s"),
                             ("events_per_sec", "ev/s"),
                             ("jobs_completed", "n")):
            rows.append(fmt_csv("sim_throughput", engine, metric,
                                r[metric], unit))
    by_engine = {r["engine"]: r for r in results}
    speedup = None
    if "vec" in by_engine and "ref" in by_engine:
        speedup = (by_engine["vec"]["events_per_sec"]
                   / max(by_engine["ref"]["events_per_sec"], 1e-9))
        rows.append(fmt_csv("sim_throughput", "-", "vec_over_ref",
                            f"{speedup:.1f}", "x"))
    for r in rows:
        print(r)

    meta = {
        "preset": preset,
        "n_clients": n_clients,
        "target_requests": total_requests,
        "total_util": TOTAL_UTIL,
        "horizon_s": horizon,
        "ref_fraction": ref_fraction,
        "workload": "olmo-1b fwd_infer batch=2 fusion=64 prompt=128",
        "policy": "lithos",
        "device": "a100_like",
        "collect_records": False,
    }
    if speedup is not None:
        meta["speedup_vec_over_ref"] = round(speedup, 2)
    if json_out:
        from benchmarks._persist import write_json
        write_json("sim", results, meta)

    failures = []
    if min_events_per_sec and "vec" in by_engine:
        eps = by_engine["vec"]["events_per_sec"]
        if eps < min_events_per_sec:
            failures.append(f"vec {eps:.0f} ev/s < floor "
                            f"{min_events_per_sec:.0f}")
    if assert_speedup and speedup is not None and speedup < assert_speedup:
        failures.append(f"speedup {speedup:.1f}x < {assert_speedup:.1f}x")
    if failures:
        raise RuntimeError("; ".join(failures))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="trace1m")
    ap.add_argument("--ref-fraction", type=float, default=None,
                    help="fraction of the horizon the ref engine runs "
                         "(default: 0.02 for trace1m, 1.0 for smoke)")
    ap.add_argument("--engines", default="vec,ref")
    ap.add_argument("--min-events-per-sec", type=float, default=0.0,
                    help="fail if the vec engine is slower than this")
    ap.add_argument("--assert-speedup", type=float, default=0.0,
                    help="fail if vec/ref events-per-sec ratio is below")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_SIM.json via benchmarks._persist")
    a = ap.parse_args()
    run(preset=a.preset, ref_fraction=a.ref_fraction,
        engines=tuple(s for s in a.engines.split(",") if s),
        min_events_per_sec=a.min_events_per_sec,
        assert_speedup=a.assert_speedup, json_out=a.json)
