"""Simulator + policy invariants: capacity, quota isolation, gating
semantics, work conservation, and qualitative orderings from the paper."""
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.lithos import evaluate, quotas_from_apps, run_alone
from repro.core.scheduler import LithOSConfig, LithOSScheduler
from repro.core.simulator import Simulator
from repro.core.types import DeviceSpec, Priority, Quota
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")


def hp_app(rps=20.0, name="hp"):
    return AppSpec(name, OLMO, "fwd_infer", priority=Priority.HIGH,
                   rps=rps, prompt_mix=((128, 1.0),), batch=4, fusion=8)


def be_train(name="be"):
    return AppSpec(name, LLAMA, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=2048, fusion=8)


class CapacityChecker:
    """Wraps a policy to assert slice capacity at every event."""

    def __init__(self, sim: Simulator):
        self.sim = sim

    def check(self):
        held = sum(ek.slices for ek in self.sim.in_flight.values())
        assert held <= self.sim.device.n_slices, held


@pytest.mark.parametrize("system", ["lithos", "mps", "mig", "timeslice",
                                    "priority", "reef", "tgs", "orion"])
def test_capacity_never_exceeded(system):
    apps = [hp_app(), be_train()]
    from repro.core.lithos import make_policy
    policy = make_policy(system, DEV, apps)
    sim = Simulator(DEV, apps, policy, horizon=2.0, seed=0)
    orig = sim._apply_allocations

    def checked():
        out = orig()
        held = sum(ek.slices for ek in sim.in_flight.values())
        assert held <= DEV.n_slices, (system, held)
        return out

    sim._apply_allocations = checked
    res = sim.run()
    assert res.client("hp").n_completed > 0


def test_closed_system_conserves_jobs():
    """Every arrived HP job completes by end of a long-enough horizon."""
    apps = [hp_app(rps=5.0)]
    res = evaluate("lithos", DEV, apps, horizon=10.0, seed=1)
    hp = res.client("hp")
    assert hp.n_completed > 0
    assert all(l > 0 for l in hp.latencies)


def test_lithos_quota_isolation_two_hp():
    """With per-client quotas, a bursty HP A is isolated from HP B's long
    kernels — unlike priority scheduling where they collide (Fig 13)."""
    hpa = AppSpec("hpA", OLMO, "fwd_infer", priority=Priority.HIGH,
                  quota_slices=27, rps=30.0, prompt_mix=((128, 1.0),),
                  batch=4, fusion=8)
    hpb = AppSpec("hpB", LLAMA, "llm_infer", priority=Priority.HIGH,
                  quota_slices=27, rps=0.0, prompt_mix=((4096, 1.0),),
                  decode_tokens=16, fusion=4)
    ideal = run_alone(DEV, hpa, horizon=6.0, seed=2).client("hpA").p99
    lith = evaluate("lithos", DEV, [hpa, hpb], horizon=6.0, seed=2)
    prio = evaluate("priority", DEV, [hpa, hpb], horizon=6.0, seed=2)
    p99_lith = lith.client("hpA").p99
    p99_prio = prio.client("hpA").p99
    assert p99_lith < p99_prio, (p99_lith, p99_prio)
    assert p99_lith < 5 * ideal


def test_mig_cannot_run_best_effort():
    res = evaluate("mig", DEV, [hp_app(), be_train()], horizon=2.0, seed=0)
    assert res.client("be").n_completed == 0
    assert res.client("hp").n_completed > 0


def test_reef_gates_be_when_hp_active():
    """REEF (paper re-implementation): BE only runs in HP-idle gaps, so BE
    throughput positive but HP tails bounded by one BE kernel."""
    res = evaluate("reef", DEV, [hp_app(rps=5.0), be_train()],
                   horizon=4.0, seed=0)
    assert res.client("be").n_completed >= 0
    assert res.client("hp").n_completed > 0


def test_lithos_stealing_work_conservation():
    """BE makes progress on idle HP quota slices; HP keeps its tails."""
    apps = [hp_app(rps=5.0), be_train()]
    steal = evaluate("lithos", DEV, apps, horizon=4.0, seed=3)
    from repro.core.scheduler import LithOSConfig
    nosteal = evaluate("lithos", DEV, apps, horizon=4.0, seed=3,
                       lithos_config=LithOSConfig(steal=False))
    assert steal.client("be").n_completed > nosteal.client("be").n_completed


def test_hol_ordering_matches_paper():
    """HP tail latency: lithos < mps when stacked with long-kernel BE
    (Fig 16's qualitative result)."""
    apps = [hp_app(rps=10.0), be_train()]
    lith = evaluate("lithos", DEV, apps, horizon=4.0, seed=4)
    mps = evaluate("mps", DEV, apps, horizon=4.0, seed=4)
    assert lith.client("hp").p99 < mps.client("hp").p99


def test_quotas_from_apps_partition():
    apps = [hp_app(name="a"), hp_app(name="b"), be_train()]
    q = quotas_from_apps(DEV, apps)
    assert q[0].slices + q[1].slices <= DEV.n_slices
    assert q[2].slices == 0
    assert q[0].priority == Priority.HIGH


def test_energy_accounting_positive_and_bounded():
    res = evaluate("lithos", DEV, [hp_app(rps=5.0)], horizon=2.0, seed=0)
    p_min = DEV.power(0, 1.0)
    p_max = DEV.power(DEV.n_slices, 1.0)
    assert p_min * 2.0 <= res.energy <= p_max * 2.0


def test_deterministic_given_seed():
    apps = [hp_app(rps=10.0), be_train()]
    a = evaluate("lithos", DEV, apps, horizon=2.0, seed=7)
    b = evaluate("lithos", DEV, apps, horizon=2.0, seed=7)
    assert a.client("hp").latencies == b.client("hp").latencies
    assert a.energy == pytest.approx(b.energy)
