"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes, dtypes, and atom schedules; property tests for atom
coverage (every tile executed exactly once, any order)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                # only the property test needs hypothesis; plain tests run
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels.atom_matmul.ops import atom_matmul, atom_ranges
from repro.kernels.atom_matmul.ref import matmul_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------------------
# atom_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (300, 260, 200),
                                   (64, 512, 96), (257, 129, 65)])
@pytest.mark.parametrize("n_atoms", [1, 3])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_atom_matmul_sweep(M, N, K, n_atoms, dtype):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (M, K), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N),
                          jnp.float32).astype(dtype)
    out = atom_matmul(a, b, n_atoms=n_atoms, block_m=128, block_n=128,
                      block_k=64, interpret=True)
    ref = matmul_ref(a, b)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_atom_matmul_order_free():
    """Atoms compose in any order (disjoint output tiles)."""
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (256, 128), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (128, 256), jnp.float32)
    base = atom_matmul(a, b, n_atoms=4, block_m=128, block_n=128,
                       block_k=128, interpret=True)
    perm = atom_matmul(a, b, n_atoms=4, order=(3, 1, 0, 2), block_m=128,
                       block_n=128, block_k=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(perm))


if HAS_HYPOTHESIS:
    @given(total=st.integers(1, 500), n=st.integers(1, 64))
    @settings(max_examples=200, deadline=None)
    def test_atom_ranges_cover_exactly_once(total, n):
        ranges = atom_ranges(total, n)
        seen = []
        for start, ln in ranges:
            assert ln > 0
            seen.extend(range(start, start + ln))
        assert seen == list(range(total))
else:
    def test_atom_ranges_cover_exactly_once():
        pytest.skip("hypothesis not installed")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hk,D", [(2, 96, 4, 2, 32), (1, 128, 8, 8, 64),
                                         (2, 64, 4, 1, 32)])
@pytest.mark.parametrize("n_atoms", [1, 3])
def test_flash_attention_sweep(B, S, Hq, Hk, D, n_atoms):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hk, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, D), jnp.float32)
    o = flash_attention(q, k, v, causal=True, n_atoms=n_atoms,
                        block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 64, 4, 32), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 2, 32),
                          jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 2, 32),
                          jnp.float32).astype(jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                        interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,Hq,Hk,D,S", [(2, 8, 2, 64, 128), (3, 4, 4, 32, 100),
                                         (1, 8, 1, 64, 48)])
@pytest.mark.parametrize("n_atoms", [1, 2])
def test_decode_attention_sweep(B, Hq, Hk, D, S, n_atoms):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, Hq, D), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hk, D), jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, D), jnp.float32)
    lens = jnp.asarray(np.random.default_rng(0).integers(1, S + 1, B),
                       jnp.int32)
    out = decode_attention(q, kc, vc, lens, n_atoms=n_atoms, block_k=32,
                           interpret=True)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_per_slot_lengths():
    """Continuous-batching: each row attends over exactly its own length."""
    key = jax.random.PRNGKey(7)
    B, Hq, Hk, D, S = 4, 4, 2, 32, 64
    q = jax.random.normal(key, (B, Hq, D), jnp.float32)
    kc = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hk, D), jnp.float32)
    vc = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, D), jnp.float32)
    lens = jnp.array([1, 17, 32, 64], jnp.int32)
    full = decode_attention(q, kc, vc, lens, block_k=16, interpret=True)
    for i, l in enumerate([1, 17, 32, 64]):
        solo = decode_attention(q[i:i+1], kc[i:i+1, :l], vc[i:i+1, :l],
                                jnp.array([l], jnp.int32), block_k=16,
                                interpret=True)
        np.testing.assert_allclose(np.asarray(full[i]), np.asarray(solo[0]),
                                   rtol=1e-5, atol=1e-5)
