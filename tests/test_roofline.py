"""Roofline pipeline tests: trip-count-aware HLO analyzer vs closed-form
programs; collective parser; workload trace sanity (6ND)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.core.costmodel import CostModel
from repro.core.types import DeviceSpec
from repro.core.workloads import (decode_step_trace, prefill_trace,
                                  train_step_trace)
from repro.roofline.hlo import collective_bytes
from repro.roofline.hlo_cost import analyze, xla_cost_dict


def test_analyzer_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return jnp.dot(c, w,
                           preferred_element_type=jnp.float32).astype(
                c.dtype), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    cost = analyze(comp.as_text())
    expected = 7 * 2 * 64 ** 3
    assert expected <= cost.flops <= 1.05 * expected
    # XLA's own analysis counts the body once — the bug we correct
    xla = float(xla_cost_dict(comp.cost_analysis()).get("flops", 0.0))
    assert xla < 0.5 * expected


def test_analyzer_nested_scans():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.dot(c2, w, preferred_element_type=jnp.float32
                               ).astype(c2.dtype), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(g).lower(x, w).compile()
    cost = analyze(comp.as_text())
    expected = 15 * 2 * 32 ** 3
    assert expected <= cost.flops <= 1.1 * expected


def test_collective_parser_on_synthetic_hlo():
    hlo = """
ENTRY %main (p: f32[16,1024]) -> f32[16,1024] {
  %p = f32[16,1024]{1,0} parameter(0)
  %ar = f32[16,1024]{1,0} all-reduce(%p), replica_groups=[4,8]<=[32], to_apply=%add
  %ag = f32[64,1024]{1,0} all-gather(%p), replica_groups=[8,4]<=[32], dimensions={0}
  ROOT %out = f32[16,1024]{1,0} add(%ar, %p)
}
"""
    by = collective_bytes(hlo)
    n = 16 * 1024 * 4
    assert by["all-reduce"] == pytest.approx(2 * n * 7 / 8)
    assert by["all-gather"] == pytest.approx(4 * n * 3 / 4)


def test_trace_flops_match_6nd():
    """Workload-compiler train traces land within 2x of 6·N·D."""
    for arch in ("llama3-8b", "olmo-1b"):
        cfg = get_config(arch)
        B, S = 4, 2048
        ops = train_step_trace(cfg, B, S)
        total = sum(op.flops for op in ops)
        model = 6.0 * cfg.param_count() * B * S
        assert 0.6 * model < total < 2.0 * model, (arch, total / model)


def test_decode_trace_memory_bound():
    cfg = get_config("llama3-8b")
    dev = DeviceSpec.a100_like()
    cm = CostModel(dev)
    ops = decode_step_trace(cfg, 1, 8192)
    big = max(ops, key=lambda o: o.bytes)
    assert not cm.is_compute_bound(big.work())


def test_prefill_trace_compute_heavier_than_decode():
    cfg = get_config("llama3-8b")
    pre = sum(op.flops for op in prefill_trace(cfg, 1, 8192))
    dec = sum(op.flops for op in decode_step_trace(cfg, 1, 8192))
    assert pre > 100 * dec
