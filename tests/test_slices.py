"""SliceMap subsystem: unit semantics + whole-simulation conservation
invariants (owned + pool + held partitions the device at every event, no
slice held by two kernels, steal ledger consistent with the paper-facing
``stolen_slice_seconds`` metric)."""
import pytest

from repro.configs.registry import get_config
from repro.core.lithos import make_policy
from repro.core.scheduler import LithOSConfig
from repro.core.simulator import Simulator
from repro.core.slices import SliceMap, VecSliceMap
from repro.core.types import DeviceSpec, Priority, Quota
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")


def hp_app(rps=20.0, name="hp"):
    return AppSpec(name, OLMO, "fwd_infer", priority=Priority.HIGH,
                   rps=rps, prompt_mix=((128, 1.0),), batch=4, fusion=8)


def be_train(name="be"):
    return AppSpec(name, LLAMA, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=2048, fusion=8)


# -- unit semantics ----------------------------------------------------------

def test_from_quotas_layout_and_counts():
    sm = SliceMap.from_quotas(10, {0: Quota(4, Priority.HIGH),
                                   1: Quota(3, Priority.BEST_EFFORT)})
    assert sm.owned_by(0) == 4 and sm.owned_by(1) == 3
    assert sm.owner[:4] == [0] * 4 and sm.owner[4:7] == [1] * 3
    assert sm.owner[7:] == [None] * 3
    assert sm.idle_pool() == [7, 8, 9]
    c = sm.counts()
    assert c["owned_idle"] + c["pool_idle"] + c["held"] == 10
    sm.check()


def test_free_for_ordering_own_pool_stolen():
    sm = SliceMap.from_quotas(8, {0: Quota(3), 1: Quota(3)})
    # own (0,1,2) then pool (6,7) then lender-1 slices (3,4,5)
    assert sm.free_for(0, lenders=[1]) == [0, 1, 2, 6, 7, 3, 4, 5]
    assert sm.free_for(0) == [0, 1, 2, 6, 7]


def test_acquire_release_and_double_hold_rejected():
    sm = SliceMap.from_quotas(6, {0: Quota(3), 1: Quota(3)})
    stolen = sm.acquire([0, 1], kid=100, borrower=0, now=1.0, eta=0.5)
    assert not stolen                       # own slices are not steals
    assert sm.holder[0] == 100 and sm.busy_until[0] == pytest.approx(1.5)
    assert sm.n_own_idle(0) == 1
    with pytest.raises(AssertionError):
        sm.acquire([1], kid=200, borrower=1, now=1.0)
    sm.check()
    freed = sm.release(100, now=2.0)
    assert set(freed) == {0, 1}
    assert sm.n_own_idle(0) == 3 and sm.holder[0] is None
    sm.check()


def test_steal_ledger_opens_and_closes():
    sm = SliceMap.from_quotas(6, {0: Quota(3), 1: Quota(3)})
    stolen = sm.acquire([2, 3], kid=7, borrower=1, now=0.0, eta=1.0)
    assert stolen                           # slice 2 belongs to client 0
    assert len(sm.ledger) == 1              # only the cross-owner slice
    rec = sm.ledger[0]
    assert (rec.slice_id, rec.owner, rec.borrower, rec.kid) == (2, 0, 1, 7)
    assert rec.open
    sm.check()
    sm.release(7, now=2.5)
    assert not rec.open and rec.duration == pytest.approx(2.5)
    assert sm.lent_slice_seconds == pytest.approx(2.5)
    sm.check()


def test_pool_acquisition_is_not_a_steal():
    sm = SliceMap.from_quotas(4, {0: Quota(2)})
    assert not sm.acquire([2, 3], kid=1, borrower=0, now=0.0)
    assert sm.ledger == []
    sm.check()


@pytest.mark.parametrize("cls", [SliceMap, VecSliceMap])
def test_disown_returns_grant_to_pool(cls):
    """The elastic half of ownership: the control plane grants pool slices
    at admission (assign_owner) and disown returns them at exit."""
    sm = cls.from_quotas(6, {0: Quota(2)})
    sm.assign_owner(4, 1)
    sm.assign_owner(5, 1)
    assert sm.owned_by(1) == 2 and sorted(sm.idle_pool()) == [2, 3]
    sm.disown(4)
    assert sm.owned_by(1) == 1 and 4 in sm.idle_pool()
    sm.disown(5)
    assert sm.owned_by(1) == 0
    assert sorted(sm.idle_pool()) == [2, 3, 4, 5]
    sm.check()


@pytest.mark.parametrize("cls", [SliceMap, VecSliceMap])
def test_disown_held_rejected_partial_grant_survives(cls):
    sm = cls.from_quotas(4, {})
    sm.assign_owner(0, 7)
    sm.assign_owner(1, 7)
    sm.acquire([0], kid=9, borrower=7, now=0.0, eta=1.0)
    with pytest.raises(AssertionError):
        sm.disown(0)                        # held: non-preemptible
    sm.disown(1)                            # the idle half releases fine
    assert sm.owned_by(7) == 1
    sm.release(9, now=1.0)                  # owner's free-list must survive
    sm.check()
    sm.disown(0)
    assert sm.owned_by(7) == 0 and sorted(sm.idle_pool()) == [0, 1, 2, 3]
    sm.check()


@pytest.mark.parametrize("cls", [SliceMap, VecSliceMap])
def test_disown_pool_slice_is_noop(cls):
    sm = cls.from_quotas(3, {0: Quota(1)})
    sm.disown(2)
    assert sorted(sm.idle_pool()) == [1, 2]
    sm.check()


# -- whole-simulation invariants --------------------------------------------

def _run_checked(system, apps, horizon=2.0, seed=0, lithos_config=None):
    policy = make_policy(system, DEV, apps, lithos_config=lithos_config)
    sim = Simulator(DEV, apps, policy, horizon=horizon, seed=seed)
    orig = sim._apply_allocations
    n_checks = [0]

    def checked():
        out = orig()
        policy.slices.check()
        c = policy.slices.counts()
        assert (c["owned_idle"] + c["pool_idle"] + c["held"]
                == DEV.n_slices)
        n_checks[0] += 1
        return out

    sim._apply_allocations = checked
    res = sim.run()
    assert n_checks[0] > 0
    policy.slices.check()
    return res, policy


def test_lithos_conservation_every_event():
    res, policy = _run_checked("lithos", [hp_app(), be_train()], seed=3)
    assert res.client("hp").n_completed > 0
    # steal scenario: BE trainer runs on HP quota -> ledger + metric agree
    assert policy.slices.lent_slice_seconds > 0
    assert policy.stolen_slice_seconds > 0
    assert all(r.t_end is None or r.t_end >= r.t_start
               for r in policy.slices.ledger)


def test_lithos_no_steal_means_empty_ledger():
    res, policy = _run_checked("lithos", [hp_app(), be_train()], seed=3,
                               lithos_config=LithOSConfig(steal=False))
    assert policy.slices.ledger == []
    assert policy.slices.lent_slice_seconds == 0.0
    assert policy.stolen_slice_seconds == 0.0


def test_mig_conservation_and_no_lending():
    res, policy = _run_checked("mig", [hp_app(), be_train()], seed=0)
    assert res.client("hp").n_completed > 0
    # MIG acquires only from its own partition: structurally no lends
    assert policy.slices.ledger == []


def test_limits_conservation():
    res, policy = _run_checked("limits", [hp_app(), be_train()], seed=0)
    assert res.client("hp").n_completed > 0
    assert policy.slices.ledger == []
