"""Substrate tests: data pipeline, optimizer (incl. int8 moments +
compression), checkpointing (crash consistency, elastic restore),
serving engine, distributed coordinator, elastic mesh math."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                # only the property test needs hypothesis; plain tests run
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.checkpoint.sharded import (CheckpointManager, latest_step,
                                      restore_checkpoint, save_checkpoint)
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed.coordinator import (Coordinator, CoordinatorConfig,
                                           HostState)
from repro.distributed.elastic import elastic_mesh_shapes, survivors
from repro.optim.optimizers import (AdamWConfig, QTensor, adamw_init,
                                    adamw_update, dequantize, quantize)
from repro.serve.engine import ServeConfig, SlotServer
from repro.train.step import TrainConfig, make_train_step


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_packed():
    dc = DataConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    a = next(SyntheticLM(dc).batches())
    b = next(SyntheticLM(dc).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (4, 64)
    # labels are next-token shifted
    rows = next(SyntheticLM(dc).packed_rows(0, 1))
    np.testing.assert_array_equal(rows[:, 1:],
                                  np.where(a["labels"] >= 0, a["labels"],
                                           rows[:, 1:]))


def test_data_shards_disjoint_streams():
    dc = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=0)
    s0 = next(SyntheticLM(dc).batches(shard=0, n_shards=2))
    s1 = next(SyntheticLM(dc).batches(shard=1, n_shards=2))
    assert s0["tokens"].shape == (4, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @given(st.integers(1, 4000), st.floats(0.01, 100.0))
    @settings(max_examples=50, deadline=None)
    def test_quantize_roundtrip_error_bound(n, scale):
        x = (np.random.default_rng(n).standard_normal(n) * scale).astype(
            np.float32)
        q = quantize(jnp.asarray(x))
        d = np.asarray(dequantize(q))
        blocks = -(-n // 256)
        for b in range(blocks):
            blk = x[b * 256:(b + 1) * 256]
            step = np.abs(blk).max() / 127.0
            np.testing.assert_allclose(d[b * 256:(b + 1) * 256], blk,
                                       atol=step / 2 + 1e-9)
else:
    def test_quantize_roundtrip_error_bound():
        pytest.skip("hypothesis not installed")


def test_adamw_quadratic_convergence():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}        # d/dw w^2
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_train_features_converge(moment_dtype):
    cfg = get_config("olmo-1b").reduced()
    tc = TrainConfig(moment_dtype=moment_dtype, n_micro=2,
                     grad_compress=(moment_dtype == "int8"))
    init_state, step = make_train_step(cfg, tc)
    state = init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=4, seed=0)).batches()
    jstep = jax.jit(step)
    losses = []
    for _ in range(6):
        b = next(data)
        state, m = jstep(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] + 0.1


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree_eq(a, b):
    fa, fb = jax.tree.leaves(a), jax.tree.leaves(b)
    for x, y in zip(fa, fb):
        xx = np.asarray(x)
        yy = np.asarray(y)
        if xx.dtype.kind == "V" or str(xx.dtype) == "bfloat16":
            xx, yy = xx.astype(np.float32), yy.astype(np.float32)
        if not np.allclose(xx, yy):
            return False
    return True


def test_checkpoint_roundtrip_and_gc():
    cfg = get_config("olmo-1b").reduced()
    init_state, _ = make_train_step(cfg, TrainConfig(moment_dtype="int8"))
    state = init_state(jax.random.PRNGKey(1))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(state, s)
        mgr.wait_all()
        assert latest_step(d) == 4
        kept = sorted(n for n in os.listdir(d) if n.startswith("step_"))
        assert kept == ["step_3", "step_4"]
        restored = mgr.restore(state)
        assert _tree_eq(state, restored)


def test_checkpoint_crash_consistency():
    """A step dir without COMMIT is never considered restorable."""
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        h = save_checkpoint(state, d, 5, async_write=False)
        h.wait()
        os.makedirs(os.path.join(d, "step_9"))      # torn write, no COMMIT
        assert latest_step(d) == 5
        restored = restore_checkpoint(state, d)
        assert _tree_eq(state, restored)


def test_checkpoint_elastic_restore_smaller_template_fails_loudly():
    state = {"w": jnp.zeros((4, 4)), "b": jnp.zeros(4)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(state, d, 1, async_write=False).wait()
        bad = {"w": jnp.zeros((4, 4))}
        with pytest.raises(AssertionError):
            restore_checkpoint(bad, d)


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------

def test_slotserver_matches_sequential_decode():
    """Continuous batching must produce the same tokens as serving each
    request alone (greedy decoding, same params)."""
    cfg = get_config("llama3-8b").reduced()
    sc = ServeConfig(max_slots=3, max_len=48, max_new_tokens=6)
    srv = SlotServer(cfg, serve_cfg=sc, seed=0)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, 200, int(rng.integers(4, 16))).astype(np.int32)
               for _ in range(5)]
    for p in prompts:
        srv.submit(p, max_new_tokens=6)
    done = sorted(srv.run_until_drained(), key=lambda r: r.rid)

    for i, p in enumerate(prompts):
        solo = SlotServer(cfg, params=srv.params, serve_cfg=sc)
        solo.submit(p, max_new_tokens=6)
        ref = solo.run_until_drained()[0]
        assert done[i].output == ref.output, i


def test_slotserver_slot_reuse_under_load():
    cfg = get_config("olmo-1b").reduced()
    srv = SlotServer(cfg, serve_cfg=ServeConfig(max_slots=2, max_len=32,
                                                max_new_tokens=4))
    for i in range(7):
        srv.submit(np.arange(2, 8, dtype=np.int32), max_new_tokens=3)
    done = srv.run_until_drained()
    assert len(done) == 7


# ---------------------------------------------------------------------------
# Coordinator / elastic
# ---------------------------------------------------------------------------

def test_coordinator_failure_state_machine():
    clock = [0.0]
    coord = Coordinator(4, CoordinatorConfig(suspect_after=10, fail_after=30),
                        clock=lambda: clock[0])
    failed = []
    coord.on_fail = failed.extend
    for t in range(0, 50, 5):
        clock[0] = float(t)
        for h in (0, 1, 2):                 # host 3 goes silent
            coord.heartbeat(h)
        coord.check()
    assert coord.hosts[3].state == HostState.FAILED
    assert failed == [3]
    assert sorted(coord.alive()) == [0, 1, 2]


def test_coordinator_straggler_detection_and_recovery():
    clock = [0.0]
    coord = Coordinator(4, CoordinatorConfig(straggler_factor=1.5),
                        clock=lambda: clock[0])
    flagged = []
    coord.on_straggler = flagged.append
    for step in range(6):
        clock[0] += 1.0
        for h in range(4):
            coord.report_step(h, 1.0 if h != 2 else 2.5)
        coord.check()
    assert coord.hosts[2].state == HostState.STRAGGLER
    assert flagged == [2]
    for step in range(8):                   # host 2 recovers
        clock[0] += 1.0
        for h in range(4):
            coord.report_step(h, 1.0)
        coord.check()
    assert coord.hosts[2].state == HostState.HEALTHY


def test_elastic_mesh_shapes():
    assert elastic_mesh_shapes(256, 16) == (16, 16)
    assert elastic_mesh_shapes(240, 16) == (15, 16)     # lost one host row
    assert elastic_mesh_shapes(8, 16) is None           # no full replica
    assert elastic_mesh_shapes(512, 16, pods=2) == (2, 16, 16)
    devs = list(range(32))
    surv = survivors(devs, failed_hosts=[1], devices_per_host=8)
    assert len(surv) == 24 and 8 not in surv
