"""Unit + property tests for the LithOS control-plane components:
atomizer (§4.4), predictor (§4.7), right-sizer (§4.5), DVFS (§4.6),
cost model."""
import math

import numpy as np
import pytest

try:                # only the property tests need hypothesis; plain tests run
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.atomizer import AtomizerConfig, KernelAtomizer, atom_ranges
from repro.core.costmodel import CostModel
from repro.core.dvfs import DVFSGovernor
from repro.core.predictor import LatencyPredictor
from repro.core.rightsizer import RightSizer
from repro.core.types import (CompletionRecord, DeviceSpec, KernelTask,
                              KernelWork)

DEV = DeviceSpec(n_slices=54, occupancy=8)


def mk_task(flops=1e12, bytes_=1e9, blocks=512, q=0, k=0):
    return KernelTask("op", KernelWork(flops, bytes_, blocks),
                      client_id=q, queue_id=q, ordinal=k)


def rec(task, lat, slices, f=1.0, t0=0.0):
    return CompletionRecord(task=task, t_submit=t0, t_start=t0,
                            t_end=t0 + lat, slices=slices, freq=f)


# ---------------------------------------------------------------------------
# Atomizer
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @given(blocks=st.integers(1, 10_000), pred_ms=st.floats(0.01, 100.0))
    @settings(max_examples=200, deadline=None)
    def test_atomizer_split_partitions_grid(blocks, pred_ms):
        at = KernelAtomizer()
        t = mk_task(blocks=blocks)
        n = at.plan(t, pred_ms * 1e-3)
        atoms = at.split(t, n)
        assert sum(a.work.n_blocks for a in atoms) == blocks
        total_flops = sum(a.work.flops for a in atoms)
        assert total_flops == pytest.approx(t.work.flops, rel=1e-6)
        if len(atoms) > 1:
            for i, a in enumerate(atoms):
                assert a.atom_of == (t.kid, i, len(atoms))
else:
    def test_atomizer_split_partitions_grid():
        pytest.skip("hypothesis not installed")


def test_atomizer_short_kernels_pass_through():
    at = KernelAtomizer(AtomizerConfig(min_duration=250e-6))
    t = mk_task(blocks=1000)
    assert at.plan(t, 100e-6) == 1          # too short
    assert at.plan(t, None) == 1            # unseen
    assert at.plan(t, 10e-3) > 1            # long kernel atomizes


def test_atomizer_adaptive_large_grid():
    cfg = AtomizerConfig(atom_duration=1e-3, large_grid_blocks=1000,
                         large_grid_scale=2.0)
    at = KernelAtomizer(cfg)
    small = at.plan(mk_task(blocks=999), 8e-3)
    large = at.plan(mk_task(blocks=2000), 8e-3)
    assert large <= small                   # less aggressive on huge grids


# ---------------------------------------------------------------------------
# Predictor
# ---------------------------------------------------------------------------

def test_predictor_learns_and_distinguishes_ordinals():
    p = LatencyPredictor(launch_overhead=0.0)
    a, b = mk_task(k=0), mk_task(k=1)
    for _ in range(5):
        p.observe(rec(a, 1e-3, 54))
        p.observe(rec(b, 5e-3, 54))
    assert p.predict(a, 54) == pytest.approx(1e-3, rel=0.01)
    assert p.predict(b, 54) == pytest.approx(5e-3, rel=0.01)
    assert p.predict(mk_task(k=7), 54) is None      # unseen node


def test_predictor_conservative_linear_fallback():
    """Seen at full allocation -> half the slices predicts 2x latency."""
    p = LatencyPredictor(launch_overhead=0.0)
    t = mk_task()
    p.observe(rec(t, 2e-3, 54))
    assert p.predict(t, 27) == pytest.approx(4e-3, rel=0.05)
    # frequency fallback is linear too
    assert p.predict(t, 54, f=0.5) == pytest.approx(4e-3, rel=0.05)


def test_predictor_atom_normalization():
    p = LatencyPredictor(launch_overhead=0.0)
    t = mk_task(blocks=100)
    atom = mk_task(blocks=25)
    atom.atom_of = (t.kid, 0, 4)
    atom.ordinal = t.ordinal
    p.observe(rec(atom, 1e-3, 54))          # one of 4 atoms took 1 ms
    # whole kernel ~4 ms; one atom of 4 ~1 ms
    assert p.predict(t, 54) == pytest.approx(4e-3, rel=0.05)
    assert p.predict(t, 54, n_atoms=4) == pytest.approx(1e-3, rel=0.05)


# ---------------------------------------------------------------------------
# Right-sizer
# ---------------------------------------------------------------------------

def test_rightsizer_recovers_amdahl_curve():
    """Feed exact l = m/t + b observations; decisions respect the slip."""
    m_true, b_true = 10e-3, 1e-3
    rs = RightSizer(full_slices=54, occupancy=8, slip=1.1)
    t = mk_task(blocks=54 * 8)
    rs.observe(rec(t, m_true / 54 + b_true, 54))
    rs.observe(rec(t, m_true / 1 + b_true, 1))
    fit = rs.fits[t.key()]
    assert fit.fitted
    assert fit.m == pytest.approx(m_true, rel=1e-6)
    assert fit.b == pytest.approx(b_true, rel=1e-6)
    chosen = rs.decide(t, 54)
    l_full = m_true / 54 + b_true
    l_chosen = m_true / chosen + b_true
    assert l_chosen <= 1.1 * l_full * (1 + 1e-9)
    # one fewer slice would violate the slip (minimality)
    if chosen > 1:
        assert m_true / (chosen - 1) + b_true > 1.1 * l_full


def test_rightsizer_occupancy_filter():
    rs = RightSizer(full_slices=54, occupancy=8, slip=1.1)
    tiny = mk_task(blocks=16)               # can use at most ceil(16/8)=2
    assert rs.occupancy_bound(tiny) == 2
    assert rs.decide(tiny, 54) == 2


def test_rightsizer_probe_protocol():
    rs = RightSizer(full_slices=54, occupancy=8, slip=1.1)
    t = mk_task(blocks=54 * 8)
    assert rs.probe_allocation(t, 54) == 54         # first: full
    rs.observe(rec(t, 2e-3, 54))
    assert rs.probe_allocation(t, 54) == 1          # second: one slice
    rs.observe(rec(t, 50e-3, 1))
    assert rs.probe_allocation(t, 54) is None       # fitted


if HAS_HYPOTHESIS:
    @given(m=st.floats(1e-4, 1.0), b=st.floats(1e-6, 1e-2),
           slip=st.floats(1.01, 2.0))
    @settings(max_examples=100, deadline=None)
    def test_rightsizer_decision_never_violates_slip(m, b, slip):
        rs = RightSizer(full_slices=54, occupancy=8, slip=slip)
        t = mk_task(blocks=54 * 8)
        rs.observe(rec(t, m / 54 + b, 54))
        rs.observe(rec(t, m + b, 1))
        chosen = rs.decide(t, 54)
        assert 1 <= chosen <= 54
        assert m / chosen + b <= slip * (m / 54 + b) * (1 + 1e-9)
else:
    def test_rightsizer_decision_never_violates_slip():
        pytest.skip("hypothesis not installed")


# ---------------------------------------------------------------------------
# DVFS
# ---------------------------------------------------------------------------

def test_dvfs_formula_and_quantization():
    gov = DVFSGovernor(DEV, slip=1.1)
    t = mk_task()
    # compute-bound kernel: slowdown tracks frequency linearly (s = 1)
    gov.observe(rec(t, 1e-3, 54, f=1.0))
    gov.observe(rec(t, 1e-3 / 0.8, 54, f=0.8))
    S = gov.aggregate_sensitivity()
    assert S == pytest.approx(1.0, abs=0.05)
    # f_final = 1 / (1 + k/S) = 1/1.1 = 0.909... -> quantized UP to 1.0
    # with the 0.9 state below it (conservative: lowest state >= raw)
    f = gov.target_frequency()
    raw = 1.0 / (1.0 + 0.1 / S)
    assert f >= raw
    assert f in DEV.f_states


def test_dvfs_memory_bound_goes_low():
    gov = DVFSGovernor(DEV, slip=1.1)
    t = mk_task()
    gov.observe(rec(t, 1e-3, 54, f=1.0))
    gov.observe(rec(t, 1e-3, 54, f=0.6))    # latency unchanged: s ~ 0
    assert gov.aggregate_sensitivity() < 0.05
    assert gov.target_frequency() == DEV.f_states[0]


def test_dvfs_mixed_stream_weighting():
    gov = DVFSGovernor(DEV, slip=1.1)
    cb, mb = mk_task(k=0), mk_task(k=1)
    # compute-bound dominates runtime 9:1
    for _ in range(3):
        gov.observe(rec(cb, 9e-3, 54, f=1.0))
        gov.observe(rec(mb, 1e-3, 54, f=1.0))
        gov.observe(rec(cb, 9e-3 / 0.8, 54, f=0.8))
        gov.observe(rec(mb, 1e-3, 54, f=0.8))
    S = gov.aggregate_sensitivity()
    assert 0.8 < S < 1.0                    # weighted toward compute-bound


def test_dvfs_conservative_unseen():
    gov = DVFSGovernor(DEV, slip=1.1)
    assert gov.unseen(mk_task(k=42))
    gov.observe(rec(mk_task(k=42), 1e-3, 54))
    assert not gov.unseen(mk_task(k=42))


# ---------------------------------------------------------------------------
# Cost model (simulator ground truth)
# ---------------------------------------------------------------------------

def test_costmodel_monotonic_in_slices_and_freq():
    cm = CostModel(DEV)
    w = KernelWork(1e12, 1e9, 54 * 8 * 4)
    lats_t = [cm.latency(w, t) for t in range(1, 55)]
    assert all(a >= b - 1e-12 for a, b in zip(lats_t, lats_t[1:]))
    lats_f = [cm.latency(w, 54, f) for f in (0.4, 0.6, 0.8, 1.0)]
    assert all(a >= b - 1e-12 for a, b in zip(lats_f, lats_f[1:]))


def test_costmodel_memory_bound_freq_insensitive():
    cm = CostModel(DEV)
    mem = KernelWork(1e6, 1e10, 4096)       # bytes dominate
    assert cm.latency(mem, 54, 0.5) == pytest.approx(
        cm.latency(mem, 54, 1.0), rel=1e-6)
    comp = KernelWork(1e13, 1e6, 4096)
    assert cm.latency(comp, 54, 0.5) == pytest.approx(
        2 * cm.latency(comp, 54, 1.0) - DEV.launch_overhead, rel=1e-3)


def test_costmodel_occupancy_bound():
    cm = CostModel(DEV)
    w = KernelWork(1e12, 1e6, 8)            # one slice's worth of blocks
    assert cm.latency(w, 54) == pytest.approx(cm.latency(w, 1), rel=1e-9)
