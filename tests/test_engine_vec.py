"""Vectorized engine (engine_vec) parity + stepping-API edge cases.

The contract: ``make_simulator(..., engine="vec")`` must be bit-for-bit
identical to the scalar reference on every scenario — same
CompletionRecord stream, same energy integral, same busy_slice_seconds,
same per-client slice_seconds and latency lists.  These tests enforce it
on the tier-1 scenario shapes across all systems, plus a multi-device
node run with migration.  ``scripts/parity_check.py`` is the manual loop
with longer horizons.
"""
import pytest

from repro.configs.registry import get_config
from repro.core import types as T
from repro.core.lithos import SYSTEMS, evaluate, make_policy
from repro.core.scheduler import LithOSConfig
from repro.core.simulator import Simulator, make_simulator
from repro.core.types import DeviceSpec, NodeConfig, NodeSpec, Priority
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")


def hp_app(rps=20.0, name="hp"):
    return AppSpec(name, OLMO, "fwd_infer", priority=Priority.HIGH,
                   rps=rps, prompt_mix=((128, 1.0),), batch=4, fusion=8)


def be_train(name="be"):
    return AppSpec(name, LLAMA, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=2048, fusion=8)


def rec_sig(res):
    return [(r.task.kid, r.task.queue_id, r.task.ordinal, r.t_submit,
             r.t_start, r.t_end, r.slices, r.freq) for r in res.records]


def assert_bit_identical(a, b):
    assert rec_sig(a) == rec_sig(b)
    assert a.energy == b.energy
    assert a.busy_slice_seconds == b.busy_slice_seconds
    for ca, cb in zip(a.clients, b.clients):
        assert ca.name == cb.name
        assert ca.slice_seconds == cb.slice_seconds
        assert ca.latencies == cb.latencies


def run_both(system, horizon=1.0, cfg=None, apps=None):
    out = []
    for engine in ("ref", "vec"):
        T.reset_kernel_ids()        # kid parity across the two runs
        out.append(evaluate(system, DEV, apps or [hp_app(), be_train()],
                            horizon=horizon, seed=0, engine=engine,
                            lithos_config=cfg))
    return out


@pytest.mark.parametrize("system", SYSTEMS)
def test_engine_parity_all_systems(system):
    a, b = run_both(system)
    assert len(a.records) > 0
    assert_bit_identical(a, b)


def test_engine_parity_lithos_full_features():
    """Right-sizing + DVFS exercise fswitch events, probe allocations and
    allocation growth — the allocation-change fast paths."""
    a, b = run_both("lithos", horizon=1.5,
                    cfg=LithOSConfig(rightsize=True, dvfs=True))
    assert len(a.records) > 0
    assert_bit_identical(a, b)


def cont_app(name="cont", rps=40.0):
    """Continuous-batching serving tenant: dynamic per-iteration batch
    composition (requests join/leave), arrival-time RNG draws."""
    return AppSpec(name, OLMO, "llm_continuous", priority=Priority.HIGH,
                   rps=rps, max_batch=4, decode_tokens=8, fusion=8,
                   prompt_mix=((256, 0.7), (1024, 0.3)), seed=5)


@pytest.mark.parametrize("system", SYSTEMS)
def test_engine_parity_llm_continuous(system):
    """The dynamic-batch code path (iteration jobs rebuilt every sync,
    requests joining/leaving mid-run) must hold bit-for-bit parity on
    every system — including request-level latencies and KV peaks."""
    apps = [cont_app(), be_train()]
    a, b = run_both(system, apps=apps)
    assert len(a.records) > 0
    assert_bit_identical(a, b)
    for ca, cb in zip(a.clients, b.clients):
        assert ca.req_latencies == cb.req_latencies
        assert ca.kv_peak_bytes == cb.kv_peak_bytes
    cont = a.client("cont")
    assert cont.kv_peak_bytes > 0.0      # requests were admitted
    if system == "lithos":               # contended baselines may starve
        assert cont.n_completed > 0          # iterations ran
        assert len(cont.req_latencies) > 0   # requests completed end to end


def test_engine_parity_llm_disaggregated_mix():
    """Disaggregated prefill + decode tenants alongside a continuous one:
    phase-tagged kernels, decode batch-marks, and the memory floor all
    active at once, with right-sizing on."""
    apps = [cont_app(rps=20.0),
            AppSpec("pre", LLAMA, "llm_prefill", priority=Priority.BEST_EFFORT,
                    batch=2, fusion=8, prompt_mix=((2048, 1.0),), seed=6),
            AppSpec("dec", OLMO, "llm_decode", priority=Priority.HIGH,
                    rps=10.0, batch=4, decode_tokens=6, fusion=8,
                    prompt_mix=((512, 1.0),), seed=7)]
    a, b = run_both("lithos", apps=apps,
                    cfg=LithOSConfig(rightsize=True))
    assert len(a.records) > 0
    assert_bit_identical(a, b)


def test_engine_parity_node_migration():
    """Multi-device node with the lending protocol: detach/admit/hold and
    cross-device arrival re-seeding must keep parity."""
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(rps=30.0), be_train("be0"), be_train("be1")]
    cfg = NodeConfig(migration=True, epoch=0.25, migration_cost=0.05,
                     cooldown=1.0, free_hi=0.5, free_lo=0.2, hp_depth_hi=3)
    out = []
    for engine in ("ref", "vec"):
        T.reset_kernel_ids()
        out.append(evaluate("lithos", node, apps, horizon=2.0, seed=0,
                            placement=[0, 0, 0], node_config=cfg,
                            engine=engine))
    a, b = out
    assert len(a.records) > 0
    assert rec_sig(a) == rec_sig(b)
    assert a.energy == b.energy
    for ca, cb in zip(a.clients, b.clients):
        assert ca.slice_seconds == cb.slice_seconds
        assert ca.latencies == cb.latencies


def test_engine_parity_lean_memory_mode():
    """collect_records=False must not change metrics, only retention."""
    out = []
    for engine in ("ref", "vec"):
        T.reset_kernel_ids()
        policy = make_policy("lithos", DEV, [hp_app(), be_train()])
        sim = make_simulator(DEV, [hp_app(), be_train()], policy,
                             engine=engine, horizon=1.0, seed=0,
                             collect_records=False)
        out.append(sim.run())
    a, b = out
    assert a.records == [] and b.records == []
    assert a.energy == b.energy
    assert a.busy_slice_seconds == b.busy_slice_seconds
    for ca, cb in zip(a.clients, b.clients):
        assert ca.latencies == cb.latencies


def test_event_counters_match():
    out = []
    for engine in ("ref", "vec"):
        T.reset_kernel_ids()
        policy = make_policy("lithos", DEV, [hp_app(), be_train()])
        sim = make_simulator(DEV, [hp_app(), be_train()], policy,
                             engine=engine, horizon=1.0, seed=0)
        sim.run()
        out.append(sim.events)
    assert out[0] == out[1] and out[0] > 0


# -- stepping-API edge cases --------------------------------------------------


def _fresh(engine, apps, horizon=0.5, system="lithos"):
    T.reset_kernel_ids()
    policy = make_policy(system, DEV, apps)
    return make_simulator(DEV, apps, policy, engine=engine,
                          horizon=horizon, seed=0)


@pytest.mark.parametrize("engine", ["ref", "vec"])
def test_step_event_past_horizon(engine):
    """Stepping after the end event keeps returning False, and post-horizon
    stragglers are skipped without touching state."""
    sim = _fresh(engine, [hp_app(rps=50.0)])
    sim.start()
    while sim.step_event():
        pass
    assert sim.done and sim.now <= sim.horizon
    e, n = sim.energy, sim.now
    for _ in range(3):
        assert sim.step_event() is False
    assert sim.energy == e and sim.now == n


@pytest.mark.parametrize("engine", ["ref", "vec"])
def test_detach_skips_stale_arrivals(engine):
    """Detaching a drained client invalidates its queued arrivals: the run
    completes with no events delivered to the departed client."""
    apps = [hp_app(rps=40.0, name="a"), hp_app(rps=40.0, name="b")]
    sim = _fresh(engine, apps, horizon=1.0)
    sim.start()
    detached = None
    for _ in range(10000):
        if not sim.step_event():
            break
        if detached is None:
            c = sim.client_by_id.get(1)
            if c is not None and sim.policy.client_drained(1):
                detached = sim.detach_client(1)
    assert detached is not None, "client b never drained"
    assert 1 not in sim.client_by_id
    while sim.step_event():
        pass
    assert sim.done
    # the detached client processed nothing after leaving
    n_jobs = len(detached.completed)
    assert all(j.t_finish is not None for j in detached.completed)
    assert n_jobs == len(detached.completed)


@pytest.mark.parametrize("engine", ["ref", "vec"])
def test_kill_completed_kernel_generation(engine):
    """kill() of an already-completed (or never-existing) kid is a no-op
    returning None, and stale completion events are ignored."""
    sim = _fresh(engine, [hp_app(rps=50.0)])
    sim.start()
    killed = False
    while sim.step_event():
        if not killed and sim.in_flight:
            kid = next(iter(sim.in_flight))
            task = sim.kill(kid)
            assert task is not None and task.kid == kid
            assert kid not in sim.in_flight
            assert sim.kill(kid) is None          # second kill: no-op
            killed = True
    assert killed and sim.done


@pytest.mark.parametrize("engine", ["ref", "vec"])
def test_zero_app_simulator(engine):
    """A simulator with no clients runs to the horizon: tick + end events
    only, zero records, idle-power-only energy."""
    T.reset_kernel_ids()
    policy = make_policy("mps", DEV, [])
    sim = make_simulator(DEV, [], policy, engine=engine, horizon=0.5,
                         seed=0)
    res = sim.run()
    assert sim.done and res.records == []
    assert sim.energy > 0.0            # idle power integrates over 0.5 s
    assert sim.busy_slice_seconds == 0.0
