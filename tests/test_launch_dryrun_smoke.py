"""Dry-run smoke: one small cell lowered+compiled in a subprocess (the
device-count flag must not leak into this test process), plus mesh/
sharding unit checks that run in-process on 1 device."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import get_config
from repro.models.common import logical_axes
from repro.models.sharding import spec_for_axes, resolve_rules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_dryrun_subprocess_one_cell(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "train_4k", "--force"],
        capture_output=True, text=True, env=env, timeout=560)
    assert "-> ok" in out.stdout, out.stdout + out.stderr
    path = os.path.join(SRC, "..", "reports", "dryrun", "pod16x16",
                        "olmo-1b__train_4k.json")
    cell = json.load(open(path))
    assert cell["status"] == "ok"
    assert cell["chips"] == 256
    assert cell["cost"]["flops_per_device"] > 0
    assert cell["roofline"]["useful_ratio"] > 0.5


def test_this_process_sees_one_device():
    assert len(jax.devices()) == 1     # the dry-run flag must not leak


def test_spec_divisibility_dropping():
    import numpy as np
    mesh = Mesh(np.array(jax.devices() * 1).reshape(1, 1), ("data", "model"))
    rules = resolve_rules("tp_dp", mesh)
    # kv_heads smaller than the axis: with a shape that does not divide,
    # the axis is dropped
    spec = spec_for_axes(("embed", "kv_heads", "head"), rules,
                         shape=(64, 8, 16), mesh=mesh)
    assert spec == P(None, "model", None) or spec == P(None, None, None)


def test_logical_axes_cover_all_params():
    """Every parameter leaf of every arch resolves to a logical-axes tuple
    of matching rank (None-padding means replicated, fine — but rank
    mismatches would silently mis-shard)."""
    from repro.models.registry import init_model
    for arch in ("llama3-8b", "qwen2-moe-a2.7b", "recurrentgemma-9b",
                 "whisper-small", "xlstm-1.3b"):
        cfg = get_config(arch).reduced()
        shapes = jax.eval_shape(lambda k: init_model(cfg, k),
                                jax.random.PRNGKey(0))
        axes = logical_axes(shapes)
        for (pa, ax), (ps, leaf) in zip(
                jax.tree_util.tree_flatten_with_path(
                    axes, is_leaf=lambda x: isinstance(x, tuple))[0],
                jax.tree_util.tree_flatten_with_path(shapes)[0]):
            assert len(ax) == leaf.ndim, (arch, pa, ax, leaf.shape)
