"""Per-architecture smoke tests: reduced same-family config, one forward /
train step and a prefill+decode round-trip on CPU; asserts output shapes
and finiteness.  The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ALL_SHAPES, ARCH_IDS, get_config
from repro.models.registry import (init_model, serve_decode, serve_prefill,
                                   train_loss)


def _batch(cfg, B=2, S=24):
    batch = {}
    if cfg.frontend == "none":
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    elif cfg.frontend == "patch_stub":
        batch["input_embeds"] = jnp.zeros((B, S, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "frame_stub":
        batch["frames"] = jnp.zeros((B, 32, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = jnp.ones((B, S), jnp.int32)
    batch["labels"] = jnp.ones((B, S), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    loss, metrics = train_loss(params, cfg, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_serve_roundtrip(arch):
    cfg = get_config(arch).reduced()
    params = init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    logits, caches = serve_prefill(params, cfg, batch, max_len=32)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches = serve_decode(params, cfg, tok, jnp.int32(S), caches)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_config(arch):
    """The full config matches the assignment table exactly."""
    cfg = get_config(arch)
    table = {
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    L, d, h, kv, ff, v = table[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v


def test_moe_configs():
    q = get_config("qwen2-moe-a2.7b")
    assert q.moe.n_experts == 60 and q.moe.top_k == 4
    assert q.moe.n_shared_experts == 4
    g = get_config("grok-1-314b")
    assert g.moe.n_experts == 8 and g.moe.top_k == 2


def test_shape_applicability_covers_40_cells():
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in ALL_SHAPES:
            rows.append((arch, s.name, *cfg.shape_applicable(s)))
    assert len(rows) == 40
    skips = [r for r in rows if not r[2]]
    # long_500k runs only for the sub-quadratic archs
    runs_500k = [r[0] for r in rows if r[1] == "long_500k" and r[2]]
    assert sorted(runs_500k) == ["recurrentgemma-9b", "xlstm-1.3b"]
    # whisper skips the >448-token serving shapes
    whisper_skips = [r[1] for r in skips if r[0] == "whisper-small"]
    assert set(whisper_skips) == {"prefill_32k", "decode_32k", "long_500k"}


def test_param_counts_sane():
    approx = {"llama3-8b": 8.0e9, "nemotron-4-340b": 341e9,
              "qwen1.5-32b": 32.5e9, "olmo-1b": 1.3e9,
              "grok-1-314b": 314e9}
    for arch, expect in approx.items():
        n = get_config(arch).param_count()
        assert 0.75 * expect < n < 1.30 * expect, (arch, n, expect)
