"""Policy.export_client_state / import_client_state round-trip contract.

The base-class docstring promises: export removes the client's state from
the source policy and returns a dict that, passed to import_client_state
on a target policy, reproduces the client's scheduling state — for
LithOSScheduler that means identical predictor weights (the warm latency
estimates that make post-migration dispatch accurate) and the preserved
quota.  A policy that exports state the importer silently drops breaks
migration warm-start; this test pins the contract.
"""
import dataclasses

from repro.configs.registry import get_config
from repro.core import types as T
from repro.core.lithos import make_policy
from repro.core.simulator import make_simulator
from repro.core.types import DeviceSpec, Priority, Quota
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")


def apps():
    return [AppSpec("hp", OLMO, "fwd_infer", priority=Priority.HIGH,
                    rps=20.0, prompt_mix=((128, 1.0),), batch=4, fusion=8,
                    quota_slices=DEV.n_slices),
            AppSpec("be", LLAMA, "fwd_infer", priority=Priority.BEST_EFFORT,
                    rps=3.0, prompt_mix=((256, 1.0),), batch=1, fusion=8)]


def warm_policy():
    """Run a short sim so the predictor accumulates observations for the
    BE client (cid 1), then return the policy once the client is drained."""
    T.reset_kernel_ids()
    policy = make_policy("lithos", DEV, apps())
    sim = make_simulator(DEV, apps(), policy, horizon=1.5, seed=0)
    sim.run()
    assert policy.client_drained(1), "BE client still has work at horizon"
    return policy


def node_snapshot(predictor, cid):
    return {k: (dict(v.lat), v.count, v.total_runtime)
            for k, v in predictor.nodes.items() if k[0] == cid}


def test_lithos_export_import_round_trip():
    src = warm_policy()
    before = node_snapshot(src.predictor, 1)
    assert before, "predictor never learned the BE client's kernels"
    quota_before = src.quotas[1]

    state = src.export_client_state(1)
    # export is destructive on the source
    assert node_snapshot(src.predictor, 1) == {}
    assert 1 not in src.quotas

    T.reset_kernel_ids()
    dst = make_policy("lithos", DEV, apps()[:1])   # target knows only hp
    make_simulator(DEV, apps()[:1], dst, horizon=0.5, seed=1)
    dst.import_client_state(1, Priority.BEST_EFFORT, state)

    # identical predictor weights: same nodes, same (slices, f) -> EWMA
    # tables, same counts — not approximately, exactly
    assert node_snapshot(dst.predictor, 1) == before
    assert dst.quotas[1] == quota_before


def test_lithos_export_import_preserves_scheduling_behavior():
    """A target that imported the state predicts exactly what the source
    would have predicted for the migrated client's kernels."""
    src = warm_policy()
    keys = [k for k in src.predictor.nodes if k[0] == 1]
    probes = []
    for k in keys[:8]:
        node = src.predictor.nodes[k]
        for (slices, fk) in list(node.lat)[:2]:
            probes.append((k, slices, fk, node.lat[(slices, fk)]))
    state = src.export_client_state(1)

    dst = make_policy("lithos", DEV, apps()[:1])
    make_simulator(DEV, apps()[:1], dst, horizon=0.5, seed=1)
    dst.import_client_state(1, Priority.BEST_EFFORT, state)
    for k, slices, fk, expected in probes:
        assert dst.predictor.nodes[k].lat[(slices, fk)] == expected


def test_export_requires_drained_client():
    T.reset_kernel_ids()
    policy = make_policy("lithos", DEV, apps())
    sim = make_simulator(DEV, apps(), policy, horizon=1.0, seed=0)
    sim.start()
    # step until the BE client has something in flight, then export must
    # refuse (the node layer only migrates drained queues)
    for _ in range(5000):
        if not sim.step_event():
            break
        if not policy.client_drained(1):
            try:
                policy.export_client_state(1)
                raise RuntimeError("export accepted an undrained client")
            except AssertionError:
                return
    raise RuntimeError("BE client was never undrained during the run")
