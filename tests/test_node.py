"""Node layer: 1-device parity with the single-device path, router
placement properties, multi-device end-to-end runs, and the
quota-derivation capacity clamp."""
import pytest

from repro.configs.registry import get_config
from repro.core.lithos import evaluate, quotas_from_apps
from repro.core.node import ROUTERS, demand_estimate, place
from repro.core.types import DeviceSpec, NodeSpec, Priority
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")
WHISPER = get_config("whisper-small")


def hp_app(rps=20.0, name="hp", cfg=OLMO, quota=0):
    return AppSpec(name, cfg, "fwd_infer", priority=Priority.HIGH,
                   rps=rps, prompt_mix=((128, 1.0),), batch=4, fusion=8,
                   quota_slices=quota)


def be_train(name="be", cfg=LLAMA):
    return AppSpec(name, cfg, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=2048, fusion=8)


# -- 1-device parity (the refactor's bit-for-bit contract) -------------------

@pytest.mark.parametrize("system", ["lithos", "mps", "mig", "limits",
                                    "reef", "timeslice"])
def test_one_device_node_parity(system):
    apps = [hp_app(), be_train()]
    a = evaluate(system, DEV, apps, horizon=2.0, seed=3)
    b = evaluate(system, NodeSpec.uniform(1, DEV), apps, horizon=2.0, seed=3)
    for ca, cb in zip(a.clients, b.clients):
        assert ca.name == cb.name and ca.cid == cb.cid
        assert ca.latencies == cb.latencies          # exact, not approx
        assert ca.n_completed == cb.n_completed
        assert ca.slice_seconds == cb.slice_seconds
    assert a.energy == b.energy
    assert a.busy_slice_seconds == b.busy_slice_seconds
    assert a.utilization == b.utilization
    assert len(a.records) == len(b.records)


@pytest.mark.parametrize("router", ROUTERS)
def test_one_device_parity_any_router(router):
    apps = [hp_app(), be_train()]
    a = evaluate("lithos", DEV, apps, horizon=1.0, seed=0)
    b = evaluate("lithos", NodeSpec.uniform(1, DEV), apps, horizon=1.0,
                 seed=0, router=router)
    assert a.client("hp").latencies == b.client("hp").latencies
    assert b.placement == [0, 0]


# -- routers ----------------------------------------------------------------

def test_routers_deterministic_and_in_range():
    node = NodeSpec.uniform(3, DEV)
    apps = [hp_app(name="a"), hp_app(name="b", cfg=WHISPER),
            be_train(name="c"), be_train(name="d", cfg=OLMO),
            hp_app(name="e", rps=5.0)]
    for router in ROUTERS:
        p1 = place(node, apps, router)
        p2 = place(node, apps, router)
        assert p1 == p2
        assert all(0 <= d < node.n_devices for d in p1)
        assert len(p1) == len(apps)


def test_least_loaded_spreads_trainers():
    node = NodeSpec.uniform(2, DEV)
    apps = [be_train(name="t1"), be_train(name="t2")]
    p = place(node, apps, "least_loaded")
    assert sorted(p) == [0, 1]             # one soaker per device


def test_quota_aware_avoids_oversubscription():
    node = NodeSpec.uniform(2, DEV)
    big = DEV.n_slices - 10
    apps = [hp_app(name="a", quota=big), hp_app(name="b", quota=big),
            be_train(name="c"), be_train(name="d")]
    p = place(node, apps, "quota_aware")
    assert p[0] != p[1]                    # both guarantees fit un-clipped
    assert sorted(p[2:]) == [0, 1]         # BE spread by count


def test_quota_aware_sizes_quotas_per_device():
    """Heterogeneous node: a guarantee is checked against each device's own
    capacity, not devices[0]'s."""
    from dataclasses import replace as dc_replace
    small = dc_replace(DEV, n_slices=27)
    node = NodeSpec(devices=(small, DEV))          # small listed first
    apps = [hp_app(name="big", quota=50), hp_app(name="small_q", quota=20)]
    p = place(node, apps, "quota_aware")
    assert p[0] == 1                               # 50 only fits on 54 slices


def test_affinity_colocates_same_arch():
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(name="a", cfg=OLMO), hp_app(name="b", cfg=WHISPER),
            hp_app(name="c", cfg=OLMO, rps=5.0)]
    p = place(node, apps, "affinity")
    assert p[0] == p[2]                    # both olmo replicas together
    assert p[0] != p[1]                    # whisper on the other device


def test_demand_estimate_bounds():
    assert demand_estimate(be_train(), DEV) == 1.0
    d = demand_estimate(hp_app(rps=1.0), DEV)
    assert 0.0 < d <= 1.0


def test_unknown_router_raises():
    with pytest.raises(ValueError):
        place(NodeSpec.uniform(2, DEV), [hp_app()], "random")


# -- multi-device end-to-end -------------------------------------------------

def test_two_device_node_runs_and_aggregates():
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(name="hpA"), hp_app(name="hpB", cfg=WHISPER, rps=10.0),
            be_train(name="beA"), be_train(name="beB", cfg=OLMO)]
    res = evaluate("lithos", node, apps, horizon=2.0, seed=1,
                   router="least_loaded")
    assert len(res.clients) == 4
    assert {c.name for c in res.clients} == {"hpA", "hpB", "beA", "beB"}
    assert res.client("hpA").n_completed > 0
    assert 0.0 < res.utilization <= 1.0
    assert res.energy > 0
    # per-device records only mention clients placed on that device
    for d, r in enumerate(res.per_device):
        cids_here = {i for i, p in enumerate(res.placement) if p == d}
        assert {rec.task.client_id for rec in r.records} <= cids_here
    # a tenant keeps its node-global cid and hence its workload stream
    assert [c.cid for c in res.clients] == [0, 1, 2, 3]


def test_client_keeps_workload_stream_across_placements():
    """Same tenant, different routers -> same arrival process (cids are
    node-global, so placement never resamples a client's randomness)."""
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(name="hpA"), hp_app(name="hpB", cfg=WHISPER, rps=10.0),
            be_train(name="beA"), be_train(name="beB", cfg=OLMO)]
    r1 = evaluate("lithos", node, apps, horizon=1.0, seed=5,
                  router="round_robin")
    r2 = evaluate("lithos", node, apps, horizon=1.0, seed=5,
                  router="affinity")
    a1 = sorted(r1.client("hpA").arrivals)
    a2 = sorted(r2.client("hpA").arrivals)
    # completed-job arrival times come from the same Poisson stream
    common = min(len(a1), len(a2))
    assert common > 0 and a1[:common] == a2[:common]


def test_mig_on_node_still_strands_be():
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(name="hpA"), hp_app(name="hpB", cfg=WHISPER, rps=10.0),
            be_train(name="beA"), be_train(name="beB", cfg=OLMO)]
    res = evaluate("mig", node, apps, horizon=1.0, seed=0)
    assert res.client("beA").n_completed == 0
    assert res.client("beB").n_completed == 0
    assert res.client("hpA").n_completed > 0


# -- quota derivation clamp (capacity is a hard ceiling) ---------------------

def test_quotas_clamped_to_device_capacity():
    apps = [hp_app(name="a", quota=DEV.n_slices + 40),
            hp_app(name="b"),                      # derived
            be_train(name="c")]
    q = quotas_from_apps(DEV, apps)
    assert sum(x.slices for x in q.values()) <= DEV.n_slices
    assert q[0].slices == DEV.n_slices             # clamped, not 94
    assert q[1].slices == 0                        # nothing left to promise
    assert q[2].slices == 0


def test_quotas_derived_split_unchanged_when_capacity_fits():
    apps = [hp_app(name="a"), hp_app(name="b"), be_train(name="c")]
    q = quotas_from_apps(DEV, apps)
    assert q[0].slices == q[1].slices == DEV.n_slices // 2
    assert sum(x.slices for x in q.values()) <= DEV.n_slices


def test_explicit_quota_reserved_before_derived_shares():
    """An explicit guarantee that fits on its own must not be degraded to
    cover the >=1-slice floor of derived shares handed out earlier."""
    apps = [hp_app(name=f"d{i}") for i in range(5)] + \
           [hp_app(name="explicit", quota=50)]
    q = quotas_from_apps(DEV, apps)
    assert q[5].slices == 50                       # reserved first
    assert sum(x.slices for x in q.values()) <= DEV.n_slices
    assert all(q[i].slices in (0, 1) for i in range(5))


def test_quotas_with_global_cids():
    apps = [hp_app(name="a"), be_train(name="c")]
    q = quotas_from_apps(DEV, apps, cids=[7, 42])
    assert set(q) == {7, 42}
    assert q[7].priority == Priority.HIGH
