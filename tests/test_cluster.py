"""Cluster tier: 1-node parity with evaluate_node (the refactor's
bit-for-bit contract, one level up), cross-node stealing with cluster-wide
ledger conservation under both engines, heterogeneous-capacity placement
feeding the fragmentation metric, and power capping."""
import pytest

from repro.configs.registry import get_config
from repro.core import types as T
from repro.core.cluster import (CLUSTER_ROUTERS, evaluate_cluster,
                                place_cluster)
from repro.core.lithos import evaluate
from repro.core.node import evaluate_node
from repro.core.types import (ClusterConfig, ClusterSpec, DeviceSpec,
                              NodeConfig, NodeSpec, Priority)
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()
L4 = DeviceSpec.l4_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")
ENGINES = ("ref", "vec")

STEAL_NODE = NodeConfig(migration=True, epoch=0.1, migration_cost=0.02,
                        cooldown=5.0, validate=True)
STEAL_CLUSTER = ClusterConfig(migration=True, epoch=0.2,
                              migration_cost=0.05, cooldown=5.0,
                              hp_depth_hi=2, validate=True)


def hp_app(rps=20.0, name="hp", cfg=OLMO, quota=0):
    return AppSpec(name, cfg, "fwd_infer", priority=Priority.HIGH,
                   rps=rps, prompt_mix=((128, 1.0),), batch=4, fusion=8,
                   quota_slices=quota)


def be_train(name="be", cfg=LLAMA):
    return AppSpec(name, cfg, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=2048, fusion=8)


def saturated_plus_idle_node():
    """Everything pinned on node 0 (stale forecast), node 1 empty — the
    canonical lender shape, one level up from the PR 2 benchmark."""
    cluster = ClusterSpec.uniform(2, NodeSpec.uniform(2, DEV))
    apps = [hp_app(name="hp0", rps=40.0), hp_app(name="hp1", rps=30.0),
            be_train(name="be0"), be_train(name="be1", cfg=OLMO)]
    placement = [(0, 0), (0, 1), (0, 0), (0, 1)]
    return cluster, apps, placement


# -- 1-node parity (the refactor's bit-for-bit contract, one level up) -------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("system", ["lithos", "mps"])
def test_one_node_cluster_parity_exact(engine, system):
    """records, energy, latencies — exact, per the acceptance criteria."""
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(), hp_app(name="hp2", rps=10.0), be_train()]
    T.reset_kernel_ids()
    a = evaluate_node(system, node, apps, horizon=1.5, seed=3,
                      engine=engine)
    T.reset_kernel_ids()
    b = evaluate_cluster(system, ClusterSpec(nodes=(node,)), apps,
                         horizon=1.5, seed=3, router="least_loaded",
                         engine=engine)
    assert a.records == b.records
    assert a.energy == b.energy
    assert a.busy_slice_seconds == b.busy_slice_seconds
    for ca, cb in zip(a.clients, b.clients):
        assert ca.name == cb.name and ca.cid == cb.cid
        assert ca.latencies == cb.latencies
    assert b.placement == [(0, d) for d in a.placement]


@pytest.mark.parametrize("engine", ENGINES)
def test_one_node_cluster_parity_with_intra_node_stealing(engine):
    """The member node's own lending protocol behaves identically whether
    the node runs standalone or driven event-by-event by the cluster."""
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(name="hp0", rps=40.0), be_train(name="be0"),
            be_train(name="be1", cfg=OLMO)]
    placement = [0, 0, 0]
    T.reset_kernel_ids()
    a = evaluate_node("lithos", node, apps, horizon=2.0, seed=7,
                      node_config=STEAL_NODE, placement=placement,
                      engine=engine)
    T.reset_kernel_ids()
    b = evaluate_cluster("lithos", ClusterSpec(nodes=(node,)), apps,
                         horizon=2.0, seed=7,
                         cluster_config=ClusterConfig(
                             node_config=STEAL_NODE),
                         placement=[(0, d) for d in placement],
                         engine=engine)
    assert a.records == b.records
    assert a.energy == b.energy
    assert a.migrations == b.per_node[0].migrations
    for ca, cb in zip(a.clients, b.clients):
        assert ca.latencies == cb.latencies


def test_cluster_dispatch_through_evaluate():
    cluster = ClusterSpec.uniform(2, NodeSpec.uniform(1, DEV))
    res = evaluate("lithos", cluster, [hp_app(), be_train()], horizon=1.0,
                   seed=0, router="round_robin")
    assert res.cluster is cluster
    assert len(res.clients) == 2
    assert res.client("hp").n_completed > 0
    with pytest.raises(ValueError):
        evaluate("lithos", DEV, [hp_app()], horizon=1.0,
                 cluster_config=ClusterConfig())
    with pytest.raises(ValueError):
        evaluate("lithos", cluster, [hp_app()], horizon=1.0,
                 node_config=NodeConfig())


# -- cross-node stealing + cluster-wide conservation -------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_cross_node_stealing_conserves(engine):
    """The conservation property test: with cluster migration on and
    ``validate=True`` the coordinator re-checks the cluster-wide ledger at
    every epoch; here we also assert the final state explicitly."""
    cluster, apps, placement = saturated_plus_idle_node()
    T.reset_kernel_ids()
    res = evaluate_cluster("lithos", cluster, apps, horizon=3.0, seed=7,
                           cluster_config=STEAL_CLUSTER,
                           placement=placement, engine=engine)
    assert res.migrations > 0               # the idle node lent capacity
    ledger = res.ledger
    # every client hosted exactly once, by the node the ledger claims
    hosted = {}
    for ni, nc in enumerate(res.coordinator.node_coords):
        for sim in nc.sims:
            for c in sim.clients:
                assert c.cid not in hosted, f"client {c.cid} hosted twice"
                hosted[c.cid] = ni
    assert hosted == ledger.current
    res.coordinator.check()
    # open records are exactly the off-home clients
    open_recs = [r for r in ledger.ledger if r.open]
    off_home = {cid for cid, n in ledger.current.items()
                if n != ledger.home[cid]}
    assert {r.cid for r in open_recs} == off_home
    assert ledger.donated_seconds(res.horizon) > 0
    # only BE tenants moved, and each move landed in the log
    be_cids = {i for i, a in enumerate(apps)
               if a.priority == Priority.BEST_EFFORT}
    assert {cid for _, cid, _, _ in res.coordinator.migration_log} <= be_cids
    assert len(res.coordinator.migration_log) == res.migrations


def test_cross_node_stealing_helps_the_starved_trainers():
    cluster, apps, placement = saturated_plus_idle_node()
    T.reset_kernel_ids()
    static = evaluate_cluster("lithos", cluster, apps, horizon=3.0, seed=7,
                              placement=placement)
    T.reset_kernel_ids()
    steal = evaluate_cluster("lithos", cluster, apps, horizon=3.0, seed=7,
                             cluster_config=STEAL_CLUSTER,
                             placement=placement)
    be_jobs = lambda r: sum(r.client(a.name).n_completed for a in apps
                            if a.priority == Priority.BEST_EFFORT)
    assert steal.migrations > 0
    assert be_jobs(steal) > be_jobs(static)


def test_two_tier_stealing_composes():
    """Intra-node and cross-node lending run together; the frozen set keeps
    the two coordinators off the same client, and both ledgers stay
    conserved (validate=True re-checks each tier every epoch)."""
    cluster, apps, placement = saturated_plus_idle_node()
    cfg = ClusterConfig(migration=True, epoch=0.2, migration_cost=0.05,
                        cooldown=5.0, hp_depth_hi=2, validate=True,
                        node_config=STEAL_NODE)
    T.reset_kernel_ids()
    res = evaluate_cluster("lithos", cluster, apps, horizon=3.0, seed=7,
                           cluster_config=cfg, placement=placement)
    assert res.migrations + res.node_migrations > 0
    res.coordinator.check()
    for nc in res.coordinator.node_coords:
        nc.check()


# -- heterogeneous capacity + fragmentation ----------------------------------

def test_frag_aware_placement_fits_guarantees_to_capacity():
    """Asymmetric devices: a 40-slice guarantee fits no L4 (29 slices) —
    frag_aware must put it on an A100 and keep small tenants from
    stranding the big holes."""
    cluster = ClusterSpec(nodes=(NodeSpec.uniform(2, DEV),
                                 NodeSpec.uniform(2, L4)),
                          name="hetero")
    apps = [hp_app(name="big0", quota=40), hp_app(name="big1", quota=40),
            hp_app(name="small0", quota=20), hp_app(name="small1", quota=20),
            be_train(name="be0"), be_train(name="be1", cfg=OLMO)]
    pl = place_cluster(cluster, apps, "frag_aware")
    assert pl[0][0] == 0 and pl[1][0] == 0          # 40 only fits an A100
    assert pl[0] != pl[1]                           # one big hole each
    assert pl[4] != pl[5]                           # BE spread by count
    for (ni, di) in pl:
        assert 0 <= ni < cluster.n_nodes
        assert 0 <= di < cluster.nodes[ni].n_devices


def test_heterogeneous_cluster_runs_and_samples_fragmentation():
    cluster = ClusterSpec(nodes=(NodeSpec.uniform(1, DEV),
                                 NodeSpec.uniform(1, L4)),
                          name="hetero")
    apps = [hp_app(name="a", quota=40), hp_app(name="b", quota=20, rps=10.0),
            be_train(name="c")]
    T.reset_kernel_ids()
    res = evaluate_cluster("lithos", cluster, apps, horizon=2.0, seed=1,
                           router="frag_aware",
                           cluster_config=ClusterConfig(epoch=0.25))
    assert res.client("a").n_completed > 0
    assert len(res.frag_series) >= 4        # sampled on the epoch grid
    assert all(0.0 <= f <= 1.0 for _, f in res.frag_series)
    assert 0.0 <= res.frag_mean <= 1.0


def test_cluster_routers_deterministic_and_in_range():
    cluster = ClusterSpec(nodes=(NodeSpec.uniform(2, DEV),
                                 NodeSpec.uniform(2, L4)))
    apps = [hp_app(name="a"), hp_app(name="b", quota=30),
            be_train(name="c"), be_train(name="d", cfg=OLMO),
            hp_app(name="e", rps=5.0)]
    for router in CLUSTER_ROUTERS:
        p1 = place_cluster(cluster, apps, router)
        p2 = place_cluster(cluster, apps, router)
        assert p1 == p2
        assert len(p1) == len(apps)
        for (ni, di) in p1:
            assert 0 <= ni < cluster.n_nodes
            assert 0 <= di < cluster.nodes[ni].n_devices
    with pytest.raises(ValueError):
        place_cluster(cluster, apps, "random")


# -- power capping -----------------------------------------------------------

def test_power_cap_reduces_energy_and_logs():
    cluster, apps, placement = saturated_plus_idle_node()
    T.reset_kernel_ids()
    free = evaluate_cluster("lithos", cluster, apps, horizon=3.0, seed=7,
                            placement=placement)
    # half the cluster idles, so cap against the observed draw, not peak
    cap = 0.8 * free.energy / free.horizon
    T.reset_kernel_ids()
    capped = evaluate_cluster("lithos", cluster, apps, horizon=3.0, seed=7,
                              cluster_config=ClusterConfig(power_cap=cap),
                              placement=placement)
    assert capped.power_log                 # the manager ran every epoch
    assert capped.energy < free.energy
    for t, before, after, min_f in capped.power_log:
        assert after <= max(cap, before) + 1e-6
        assert min_f >= DEV.f_states[0] - 1e-9


def test_power_cap_respects_hp_floor():
    cluster, apps, placement = saturated_plus_idle_node()
    cfg = ClusterConfig(power_cap=1.0, power_hp_floor=0.8)  # infeasible cap
    T.reset_kernel_ids()
    res = evaluate_cluster("lithos", cluster, apps, horizon=2.0, seed=7,
                           cluster_config=cfg, placement=placement)
    pm = res.coordinator.power_manager
    # replay the last epoch's plan: HP devices never below the floor
    from repro.core.dvfs import plan_power_budget
    fs = plan_power_budget(pm.specs, [s.n_slices for s in pm.specs],
                           [True] * len(pm.specs), 1.0, hp_floor=0.8)
    assert all(f >= 0.8 - 1e-9 for f in fs)
