"""Level-agnostic hierarchy: generic routing, the fragmentation metric,
and the cluster power-budget planner."""
import pytest

from repro.core.dvfs import plan_power_budget
from repro.core.hierarchy import ROUTERS, fragmentation, route
from repro.core.types import DeviceSpec, Priority
from repro.core.workloads import AppSpec

DEV = DeviceSpec.a100_like()


def _app(name, prio=Priority.HIGH, quota=0, cfg_name="olmo-1b"):
    from repro.configs.registry import get_config
    return AppSpec(name, get_config(cfg_name), "fwd_infer", priority=prio,
                   rps=5.0, prompt_mix=((128, 1.0),), batch=4, fusion=8,
                   quota_slices=quota)


# -- generic routing ---------------------------------------------------------

def test_route_single_member_short_circuits():
    apps = [_app("a"), _app("b")]
    for router in ROUTERS:
        assert route([54], apps, router) == [0, 0]


def test_route_round_robin_stripes():
    apps = [_app(f"a{i}") for i in range(5)]
    assert route([54, 54, 54], apps, "round_robin") == [0, 1, 2, 0, 1]


def test_route_least_loaded_normalizes_by_capacity():
    """On a 2:1 capacity split, equal demands land 2:1."""
    apps = [_app(f"a{i}") for i in range(6)]
    pl = route([60, 30], apps, "least_loaded", demands=[1.0] * 6)
    assert pl.count(0) == 4 and pl.count(1) == 2


def test_route_quota_aware_respects_member_capacity():
    """A guarantee is checked against each member's own capacity."""
    apps = [_app("big", quota=50), _app("small", quota=20)]
    pl = route([27, 54], apps, "quota_aware")
    assert pl[0] == 1                       # 50 only fits on the 54 member


def test_route_unknown_raises():
    with pytest.raises(ValueError):
        route([54, 54], [_app("a")], "nope")


def test_route_demands_required():
    with pytest.raises(AssertionError):
        route([54, 54], [_app("a")], "least_loaded")


# -- fragmentation metric ----------------------------------------------------

def test_fragmentation_zero_when_everything_fits():
    assert fragmentation([54, 54], [10, 20, 30]) == 0.0


def test_fragmentation_one_when_nothing_fits():
    assert fragmentation([5, 3], [10, 20]) == 1.0


def test_fragmentation_degenerate_inputs():
    assert fragmentation([], [10]) == 0.0
    assert fragmentation([0, 0], [10]) == 0.0
    assert fragmentation([54], []) == 0.0


def test_fragmentation_partial():
    # free=[10, 2]: 10 hosts both demands, 2 hosts neither ->
    # stranded = 2 * 1.0, total = 12
    f = fragmentation([10, 2], [5, 8])
    assert f == pytest.approx(2.0 / 12.0)


def test_fragmentation_weighs_by_fragment_size():
    # the larger the stranded fragment, the worse the score
    assert fragmentation([9, 1], [10]) == 1.0
    assert fragmentation([20, 1], [10]) < 1.0


# -- cluster power-budget planner -------------------------------------------

def _plan(active, hp, cap, n=3, hp_floor=0.75):
    devs = [DEV] * n
    return plan_power_budget(devs, active, hp, cap, hp_floor=hp_floor)


def test_power_budget_generous_cap_is_noop():
    fs = _plan([54, 54, 54], [True, True, True], cap=1e9)
    assert fs == [1.0, 1.0, 1.0]


def test_power_budget_throttles_be_devices_first():
    full = sum(DEV.power(54, 1.0) for _ in range(3))
    # shave less than one BE device's full dynamic swing off the budget
    fs = _plan([54, 54, 54], [True, True, False], cap=full - 100.0)
    assert fs[0] == 1.0 and fs[1] == 1.0    # HP devices untouched
    assert fs[2] < 1.0                      # BE device took the cut


def test_power_budget_respects_hp_floor():
    fs = _plan([54, 54, 54], [True, True, True], cap=0.0)
    assert all(f >= 0.75 - 1e-9 for f in fs)
    fs = _plan([54, 54, 54], [False, False, False], cap=0.0)
    assert all(f == DEV.f_states[0] for f in fs)    # BE can hit the floor


def test_power_budget_meets_feasible_cap():
    full = sum(DEV.power(54, 1.0) for _ in range(3))
    floor = sum(DEV.power(54, DEV.f_states[0]) for _ in range(3))
    cap = (full + floor) / 2
    fs = _plan([54, 54, 54], [False, False, False], cap=cap)
    assert sum(DEV.power(54, f) for f in fs) <= cap + 1e-6


def test_power_budget_skips_idle_devices():
    """Throttling an idle device saves nothing; the planner must not spin
    on it, and must leave its state at f_max."""
    fs = _plan([0, 54, 54], [False, False, False], cap=0.0)
    assert fs[0] == 1.0
    assert fs[1] == fs[2] == DEV.f_states[0]


def test_power_budget_deterministic():
    args = ([30, 54, 12], [False, True, False], 900.0)
    assert _plan(*args) == _plan(*args)
