"""LLM-era tenants: continuous batching, KV-cache footprint, memory
floor, phase-aware atomization, decode-roofline calibration, and the
RNG draw-order (seed stability) contract.

Covers the PR 9 tentpole end to end at unit level; bit-for-bit engine
parity on the same code paths lives in tests/test_engine_vec.py and
scripts/parity_check.py.
"""
import math

import numpy as np
import pytest

try:                # only the property tests need hypothesis; plain tests run
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.configs.registry import get_config
from repro.core import types as T
from repro.core.atomizer import KernelAtomizer
from repro.core.costmodel import CostModel
from repro.core.hierarchy import HierarchyCoordinator, Pressure
from repro.core.lithos import evaluate, make_policy
from repro.core.llm_costs import (decode_attention_work, decode_cost_table,
                                  flash_attention_work, roofline_terms,
                                  seed_decode_predictor)
from repro.core.predictor import LatencyPredictor
from repro.core.queues import Client
from repro.core.rightsizer import RightSizer, ScalingFit
from repro.core.scheduler import LithOSConfig
from repro.core.simulator import make_simulator
from repro.core.types import (DeviceSpec, KernelTask, KernelWork, NodeConfig,
                              Priority)
from repro.core.workloads import (AppSpec, ContinuousBatchState,
                                  bucket_kv, decode_attention_op, kv_bytes,
                                  kv_bytes_per_token, kv_floor_slices,
                                  sample_prompt_len)

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")


def cont_spec(**kw):
    kw.setdefault("rps", 40.0)
    kw.setdefault("max_batch", 4)
    kw.setdefault("decode_tokens", 8)
    kw.setdefault("prompt_mix", ((256, 0.7), (1024, 0.3)))
    kw.setdefault("priority", Priority.HIGH)
    kw.setdefault("fusion", 8)
    return AppSpec(kw.pop("name", "cont"), kw.pop("cfg", OLMO),
                   "llm_continuous", **kw)


# ---------------------------------------------------------------------------
# KV footprint model
# ---------------------------------------------------------------------------


def test_kv_bytes_model():
    # 2 (K+V) * layers * kv_heads * head_dim * dsize, per token
    per_tok = kv_bytes_per_token(OLMO)
    assert per_tok == 2.0 * OLMO.n_layers * OLMO.n_kv_heads \
        * OLMO.head_dim * 2
    assert kv_bytes(OLMO, 4, 1000) == 4 * 1000 * per_tok
    assert kv_bytes(OLMO, 0, 1000) == 0.0


def test_kv_floor_slices():
    dev = DeviceSpec(n_slices=8, hbm_capacity=1e9)
    assert kv_floor_slices(OLMO, dev, 0.0) == 1
    assert kv_floor_slices(OLMO, dev, 0.5e9) == 1
    assert kv_floor_slices(OLMO, dev, 2.5e9) == 3
    assert kv_floor_slices(OLMO, dev, 1e12) == 8          # capped at device
    nocap = DeviceSpec(n_slices=8, hbm_capacity=0.0)
    assert kv_floor_slices(OLMO, nocap, 1e12) == 1        # gated off


# ---------------------------------------------------------------------------
# ContinuousBatchState invariants (hypothesis where available)
# ---------------------------------------------------------------------------


def _drive(cbs, script, now=0.0):
    """Replay a script of ('add', prompt, budget) | ('iter',) actions,
    checking the three invariants after every step.  Returns per-rid
    kv_len histories."""
    hist: dict[int, list[int]] = {}
    evicted: set[int] = set()
    for step in script:
        if step[0] == "add":
            cbs.add_request(step[1], step[2], now)
        else:
            if not cbs.has_work:
                continue
            cbs.begin_iteration()
            assert len(cbs.running) <= cbs.max_batch          # cap
            now += 1.0
            done = cbs.finish_iteration(now)
            for r in cbs.running:
                assert r.rid not in evicted
                hist.setdefault(r.rid, []).append(r.kv_len)
            for r in done:
                hist.setdefault(r.rid, []).append(r.kv_len)
                evicted.add(r.rid)
        # KV conservation across join/leave
        expect = sum(r.kv_len for r in cbs.running) \
            * cbs.per_token
        assert cbs.total_kv_bytes == pytest.approx(expect, abs=1e-3)
    for rid, seq in hist.items():
        assert all(b >= a for a, b in zip(seq, seq[1:])), \
            f"kv_len not monotone for rid {rid}: {seq}"
    return hist


if HAS_HYPOTHESIS:
    @given(cap=st.integers(1, 6),
           script=st.lists(
               st.one_of(
                   st.tuples(st.just("add"), st.integers(1, 2048),
                             st.integers(1, 6)),
                   st.tuples(st.just("iter"))),
               min_size=1, max_size=60))
    @settings(max_examples=150, deadline=None)
    def test_cbs_invariants_property(cap, script):
        cbs = ContinuousBatchState(OLMO, max_batch=cap)
        _drive(cbs, script)
else:
    def test_cbs_invariants_property():
        pytest.skip("hypothesis not installed")


def test_cbs_join_leave_accounting():
    cbs = ContinuousBatchState(OLMO, max_batch=2)
    for i in range(4):
        cbs.add_request(100, 2, arrival=float(i))
    cbs.begin_iteration()                  # admits 2, 2 wait
    assert len(cbs.running) == 2 and len(cbs.waiting) == 2
    cbs.finish_iteration(5.0)              # first token emitted
    assert cbs.total_kv_bytes == pytest.approx(
        2 * 101 * cbs.per_token)
    cbs.begin_iteration()                  # batch full: no admission
    assert len(cbs.running) == 2
    done = cbs.finish_iteration(6.0)       # budget 2 -> both leave
    assert len(done) == 2 and len(cbs.req_latencies) == 2
    cbs.begin_iteration()                  # the two waiters join
    assert len(cbs.running) == 2 and not cbs.waiting
    # KV of the leavers was reclaimed before the joiners reserved
    assert cbs.total_kv_bytes == pytest.approx(2 * 100 * cbs.per_token)
    assert cbs.kv_peak_bytes >= cbs.total_kv_bytes


def test_bucket_kv_deterministic_integer():
    assert bucket_kv(1) == 64
    assert bucket_kv(64) == 64
    assert bucket_kv(65) == 128
    assert bucket_kv(513) == 576


# ---------------------------------------------------------------------------
# RightSizer memory floor
# ---------------------------------------------------------------------------


def mk_task(blocks=512, cid=0):
    return KernelTask("op", KernelWork(1e12, 1e9, blocks), client_id=cid,
                      queue_id=cid, ordinal=0)


def test_memory_floor_clamps_fit_shrink():
    rs = RightSizer(full_slices=54, occupancy=8, slip=1.1)
    t = mk_task(blocks=5120, cid=7)
    # a serial-dominated kernel: the fit alone shrinks to ~1 slice
    fit = ScalingFit()
    fit.points = {1: 2e-3, 54: 1.9e-3}
    rs.fits[t.key()] = fit
    rs._fit(fit)
    unclamped = rs.decide(t, 54)
    assert unclamped < 10
    rs.set_memory_floor(7, 12)
    assert rs.decide(t, 54) >= 12
    # the floor never forces more than the allocation
    assert rs.decide(t, 6) == 6


def test_memory_floor_clamps_occupancy_bound():
    rs = RightSizer(full_slices=54, occupancy=8)
    t = mk_task(blocks=8, cid=3)           # occupancy bound = 1
    assert rs.decide(t, 54) == 1
    rs.set_memory_floor(3, 5)
    assert rs.decide(t, 54) == 5


def test_memory_floor_relaxes():
    rs = RightSizer(full_slices=54, occupancy=8)
    rs.set_memory_floor(3, 5)
    assert rs.memory_floor == {3: 5}
    rs.set_memory_floor(3, 1)              # requests completed: floor gone
    assert rs.memory_floor == {}


def test_memory_floor_binds_in_simulation():
    """End to end: a decode tenant whose KV cannot fit one slice is never
    right-sized below its floor — and the floor is the cause (the same
    scenario with ample HBM does shrink decode kernels)."""
    def run(hbm_capacity):
        dev = DeviceSpec(n_slices=8, hbm_capacity=hbm_capacity)
        app = cont_spec(rps=200.0, max_batch=4,
                        prompt_mix=((512, 1.0),), quota_slices=4, seed=9)
        T.reset_kernel_ids()
        res = evaluate("lithos", dev, [app], horizon=0.5, seed=3,
                       lithos_config=LithOSConfig(rightsize=True))
        return [r for r in res.records if r.task.phase == "decode"]

    # one request's KV alone needs ceil(513*per_tok / 16e6) = 5 slices
    floor_one_req = kv_floor_slices(OLMO, DeviceSpec(n_slices=8,
                                                     hbm_capacity=16e6),
                                    kv_bytes(OLMO, 1, 513))
    assert floor_one_req >= 4
    tight = run(16e6)
    assert tight and all(r.slices >= floor_one_req for r in tight)
    ample = run(1e12)
    assert ample and any(r.slices < floor_one_req for r in ample)


# ---------------------------------------------------------------------------
# Phase-aware atomization + pressure sampling
# ---------------------------------------------------------------------------


def test_atomizer_leaves_decode_whole():
    at = KernelAtomizer()
    dec = KernelTask("dec", KernelWork(1e12, 1e10, 4096), phase="decode")
    pre = KernelTask("pre", KernelWork(1e13, 1e10, 4096), phase="prefill")
    # a multi-ms prediction would normally split hard
    assert at.plan(dec, 20e-3) == 1
    assert at.plan(dec, None, unseen_conservative=True) == 1
    assert at.plan(pre, 20e-3) > 1       # prefill atomizes like training


def test_phase_flows_into_kernel_tasks():
    spec = cont_spec(rps=0.0)
    client = Client(0, spec, horizon=10.0, seed=0)
    client.cbs.add_request(100, 5, 0.0)
    client.cbs.add_request(200, 5, 0.0)
    assert client.start_next_job(0.0)
    phases = {t.phase for b in client.current.batches for t in b.tasks}
    assert phases == {"prefill"}          # first iteration: joiners only
    # drain the iteration -> both requests resident -> next one decodes
    while client.current is not None:
        client.pop()
        client.kernel_done(1.0)
    assert len(client.cbs.running) == 2
    assert client.start_next_job(2.0)
    phases = {t.phase for b in client.current.batches for t in b.tasks}
    assert phases == {"decode"}


def test_pressure_decode_depth_weighs_double():
    coord = HierarchyCoordinator.__new__(HierarchyCoordinator)
    coord.config = NodeConfig(hp_depth_hi=3, free_hi=0.5, free_lo=0.125)
    assert not coord._saturated(Pressure(1, 0.5, 1))
    assert coord._saturated(Pressure(1, 0.5, 1, decode_depth=2))
    assert coord._lender(Pressure(0, 0.9, 0))
    assert not coord._lender(Pressure(0, 0.9, 0, decode_depth=1))
    # legacy 3-arg construction still works and is decode-free
    assert Pressure(2, 0.1, 3).decode_depth == 0


def test_sim_member_pressure_counts_decode_backlog():
    from repro.core.node import SimMember
    spec = cont_spec(rps=0.0)             # manual arrivals
    policy = make_policy("lithos", DEV, [spec])
    sim = make_simulator(DEV, [spec], policy, horizon=10.0, seed=0)
    member = SimMember(sim, policy)
    assert member.pressure().decode_depth == 0
    c = sim.clients[0]
    for _ in range(6):                    # 1 in-flight + 2 waiting beyond cap
        c.on_arrival(0.0)
    p = member.pressure()
    assert p.decode_depth == len(c.cbs.waiting) + 1
    assert p.active >= 1


# ---------------------------------------------------------------------------
# Decode roofline calibration (regression pin)
# ---------------------------------------------------------------------------


def test_decode_work_matches_sim_trace_op():
    """The kernel-geometry work terms and the sim's decode trace op must
    agree exactly at block-aligned shapes (the trace op is the kernel's
    cost in the simulator)."""
    for B, S in ((1, 512), (4, 2048), (8, 8192), (2, 300)):
        kw = decode_attention_work(B, S, OLMO.n_heads, OLMO.n_kv_heads,
                                   OLMO.head_dim)
        op = decode_attention_op("d", B, S, OLMO.n_heads, OLMO.n_kv_heads,
                                 OLMO.head_dim)
        assert kw.flops == pytest.approx(op.flops, rel=1e-6)
        assert kw.bytes == pytest.approx(op.bytes, rel=1e-6)


def test_decode_cost_table_matches_roofline():
    """CostModel ground truth == roofline bound_time x wave quantization
    + launch overhead, for every calibrated decode entry.  A kernel or
    analyzer change that skews decode timings breaks this pin."""
    cost = CostModel(DEV)
    for e in decode_cost_table(LLAMA, DEV):
        ph = cost.phases(e.work)
        t_eff = max(1, min(DEV.n_slices, ph.max_useful_slices))
        quant = ph.quantization(t_eff, DEV.occupancy)
        expect = e.roofline_s * quant + DEV.launch_overhead
        assert e.latency_s == pytest.approx(expect, rel=1e-9), \
            f"B={e.batch} S={e.kv_len}"
        # decode is memory-bound by design
        assert not cost.is_compute_bound(e.work)


def test_flash_attention_work_padding_bounded():
    """Prefill (flash) work terms: padding inflates both cost views by the
    same bounded factor — never more than one block's worth per dim."""
    for B, Sq in ((1, 512), (2, 700), (4, 8192)):
        kw = flash_attention_work(B, Sq, Sq, LLAMA.n_heads,
                                  LLAMA.n_kv_heads, LLAMA.head_dim)
        ideal = 2.0 * 2.0 * B * LLAMA.n_heads * Sq * Sq * LLAMA.head_dim
        assert kw.flops >= ideal
        pad = (math.ceil(Sq / 512) * 512 / Sq) ** 2 if Sq >= 512 else 4.0
        assert kw.flops <= ideal * pad * 1.01


def test_seed_decode_predictor_warm_start():
    from repro.core.workloads import continuous_decode_trace
    pred = LatencyPredictor()
    trace = continuous_decode_trace(LLAMA, 4, 2048, 6)
    n = seed_decode_predictor(pred, 7, trace, DEV, DEV.n_slices)
    assert n == len(trace)
    cost = CostModel(DEV)
    for ordinal, op in enumerate(trace):
        t = KernelTask(op.name, op.work(), client_id=7, queue_id=7,
                       ordinal=ordinal)
        got = pred.predict(t, DEV.n_slices)
        assert got == pytest.approx(cost.latency(op.work(), DEV.n_slices),
                                    rel=1e-6)


def test_roofline_terms_effective_parallelism():
    w = KernelWork(1e12, 1e9, 8)          # tiny decode grid
    terms = roofline_terms(w, DEV)
    assert terms.chips == 1               # occupancy-capped, not 54
    big = roofline_terms(KernelWork(1e12, 1e9, 10_000), DEV)
    assert big.chips == DEV.n_slices


# ---------------------------------------------------------------------------
# Seed stability: the RNG draw-order contract (satellite 5)
# ---------------------------------------------------------------------------


def test_continuous_draw_order_pinned():
    """The continuous client's stream is: arrivals first (Poisson count +
    uniforms, at construction), then per-arrival (prompt_len, budget)
    pairs in arrival order.  Splitting a request into prefill/decode
    segments must never add or reorder draws — this golden replay breaks
    if it does."""
    spec = cont_spec(seed=5)
    client = Client(3, spec, horizon=1.0, seed=11)
    for t in client.arrivals():
        client.on_arrival(t)
    # no kernels completed: every request is still live, in arrival order
    got = [(r.prompt_len, r.decode_budget)
           for r in list(client.cbs.running) + list(client.cbs.waiting)]
    # independent replay of the documented draw order
    rng = np.random.default_rng((11, spec.seed, 3))
    arrivals = spec.arrivals(1.0, rng)
    expect = []
    for _ in arrivals:
        S = sample_prompt_len(spec.prompt_mix, rng)
        n_out = min(max(1, int(rng.geometric(1.0 / spec.decode_tokens))),
                    4 * spec.decode_tokens)
        expect.append((S, n_out))
    assert len(arrivals) > 10             # the scenario actually has load
    assert client.cbs.n_requests == len(arrivals)
    assert got == expect


def test_continuous_requests_identical_across_engines():
    """Same seed -> bit-identical request streams (prompt lens, budgets,
    kv trajectories) under ref and vec engines."""
    def requests(engine):
        T.reset_kernel_ids()
        spec = cont_spec(seed=5)
        policy = make_policy("lithos", DEV, [spec])
        sim = make_simulator(DEV, [spec], policy, horizon=1.0, seed=0,
                             engine=engine)
        sim.run()
        cbs = sim.clients[0].cbs
        return ([(r.rid, r.prompt_len, r.decode_budget, r.kv_len, r.emitted)
                 for r in list(cbs.running) + list(cbs.waiting)],
                cbs.n_requests, cbs.n_completed, cbs.req_latencies,
                cbs.total_kv_bytes, cbs.kv_peak_bytes)
    assert requests("ref") == requests("vec")


def test_legacy_llm_infer_draws_unchanged():
    """Golden pin: llm_infer's job_trace consumes exactly (S, n_out) per
    job, in that order, and stays phase-less — the phase split and the
    sample_prompt_len extraction must not perturb legacy streams."""
    spec = AppSpec("x", OLMO, "llm_infer", rps=1.0, decode_tokens=8,
                   prompt_mix=((256, 0.7), (1024, 0.3)))
    rng = np.random.default_rng(42)
    ref = np.random.default_rng(42)
    for _ in range(5):
        trace = spec.job_trace(rng)
        assert all(op.phase == "" for op in trace)
        sample_prompt_len(spec.prompt_mix, ref)
        ref.geometric(1.0 / spec.decode_tokens)
        # generator states identical after every job: same draw count,
        # same draw kinds, same order
        assert rng.bit_generator.state == ref.bit_generator.state
