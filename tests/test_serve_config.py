"""Regression: SlotServer instances must not share a ServeConfig.

The constructor used to default ``serve_cfg`` to a single module-load-time
``ServeConfig()`` instance, so tuning one server's config (e.g. raising
``max_new_tokens`` for a canary) silently retuned every other server built
with the default.
"""
import numpy as np

from repro.configs.registry import get_config
from repro.serve.engine import ServeConfig, SlotServer


def test_slotserver_default_config_not_shared():
    cfg = get_config("olmo-1b").reduced()
    s1 = SlotServer(cfg)
    s1.sc.max_new_tokens = 99
    s1.sc.max_slots = 1
    s2 = SlotServer(cfg)
    assert s2.sc.max_new_tokens == ServeConfig().max_new_tokens
    assert s2.sc.max_slots == ServeConfig().max_slots
    assert s1.sc is not s2.sc


def test_slotserver_explicit_config_still_honored():
    cfg = get_config("olmo-1b").reduced()
    sc = ServeConfig(max_slots=2, max_len=64, max_new_tokens=4)
    srv = SlotServer(cfg, serve_cfg=sc)
    assert srv.sc is sc
    srv.submit(np.arange(2, 10, dtype=np.int32))
    done = srv.run_until_drained()
    assert len(done) == 1 and len(done[0].output) <= 4
