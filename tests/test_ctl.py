"""Control-plane tests: state machine, journal, daemon lifecycle, recovery.

The crash test is the one the subsystem exists for: ``kill -9`` the daemon
mid-run, restart it against the same ``--state-dir``, and every job the
crash interrupted resumes — none lost, none duplicated.
"""
import json
import os
import signal
import subprocess
import sys
import time

import pytest

from repro.ctl import store
from repro.ctl.daemon import ControlPlane, DaemonConfig, app_from_spec, JobSpecError
from repro.ctl.state import (TERMINAL, TRANSITIONS, InvalidTransition, Job,
                             JobEvent, JobState, transition)

pytestmark = pytest.mark.ctl

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# state machine
# ---------------------------------------------------------------------------

def test_transitions_exhaustive():
    """Every (state, event) pair either transitions or raises the typed
    InvalidTransition — no pair falls through to anything else."""
    for state in JobState:
        for event in JobEvent:
            if (state, event) in TRANSITIONS:
                nxt = transition(state, event)
                assert isinstance(nxt, JobState)
            else:
                with pytest.raises(InvalidTransition) as ei:
                    transition(state, event)
                assert ei.value.state is state
                assert ei.value.event is event


def test_terminal_states_absorbing():
    for state in TERMINAL:
        rows = [e for (s, e) in TRANSITIONS if s is state]
        assert rows == [], f"{state} must have no outgoing transitions"


def test_every_state_reaches_terminal():
    """No parking state the machine can never leave: from every state some
    event path ends in a terminal state."""
    reaches = set(TERMINAL)
    changed = True
    while changed:
        changed = False
        for (s, _), dst in TRANSITIONS.items():
            if dst in reaches and s not in reaches:
                reaches.add(s)
                changed = True
    assert reaches == set(JobState)


def test_lifecycle_happy_path():
    job = Job(job_id="j", spec={})
    for ev, want in [(JobEvent.ADMIT, JobState.ADMITTED),
                     (JobEvent.START, JobState.RUNNING),
                     (JobEvent.MIGRATE, JobState.MIGRATING),
                     (JobEvent.LAND, JobState.RUNNING),
                     (JobEvent.FINISH, JobState.DONE)]:
        assert job.apply(ev) is want
    assert job.terminal and job.migrations == 1
    with pytest.raises(InvalidTransition):
        job.apply(JobEvent.CANCEL)


def test_requeue_resets_data_plane_bindings():
    job = Job(job_id="j", spec={})
    job.apply(JobEvent.ADMIT)
    job.cid, job.device, job.granted_slices = 7, 1, 8
    job.apply(JobEvent.START)
    job.apply(JobEvent.PREEMPT)
    job.apply(JobEvent.REQUEUE)
    assert job.state is JobState.QUEUED
    assert job.cid is None and job.device is None
    assert job.granted_slices == 0 and job.recoveries == 1


# ---------------------------------------------------------------------------
# journal + spool
# ---------------------------------------------------------------------------

def test_journal_replay_round_trip(tmp_path):
    d = str(tmp_path)
    j = store.Journal(d)
    j.append("a", store.SUBMIT, spec={"kind": "train"})
    j.append("a", "admit", cid=0, device=1)
    j.append("a", "start", granted=4, admitted_sim=0.0, ends_sim=2.0)
    j.append("a", "finish", result={"n_completed": 10})
    j.append("b", store.SUBMIT, spec={"kind": "serve"})
    j.append("b", "admit", cid=1, device=0)
    j.append("b", "start", granted=0, admitted_sim=0.0, ends_sim=1.0)
    j.close()
    jobs = store.replay(d)
    assert jobs["a"].state is JobState.DONE
    assert jobs["a"].result == {"n_completed": 10}
    assert jobs["a"].granted_slices == 4 and jobs["a"].device == 1
    assert jobs["b"].state is JobState.RUNNING and jobs["b"].cid == 1


def test_journal_torn_tail_ignored(tmp_path):
    d = str(tmp_path)
    j = store.Journal(d)
    j.append("a", store.SUBMIT, spec={})
    j.append("a", "admit", cid=0, device=0)
    j.close()
    with open(os.path.join(d, store.JOURNAL), "a") as f:
        f.write('{"seq": 2, "job": "a", "eve')      # crash mid-write
    jobs = store.replay(d)
    assert jobs["a"].state is JobState.ADMITTED
    # a new Journal appends after the torn line without corruption
    store.Journal(d).append("a", "start", granted=0,
                            admitted_sim=0.0, ends_sim=1.0)
    assert store.replay(d)["a"].state is JobState.ADMITTED  # torn line ends parse
    # torn tail only masks records *after* it; the journal before it holds


def test_duplicate_submit_ignored(tmp_path):
    d = str(tmp_path)
    j = store.Journal(d)
    j.append("a", store.SUBMIT, spec={"name": "first"})
    j.append("a", store.SUBMIT, spec={"name": "dup"})
    j.close()
    jobs = store.replay(d)
    assert len(jobs) == 1 and jobs["a"].spec == {"name": "first"}


def test_spool_order_and_consume(tmp_path):
    d = str(tmp_path)
    ids = [store.request_submit(d, {"i": i}) for i in range(3)]
    store.request_cancel(d, ids[1])
    submits, cancels, drain, rejected = store.scan_inbox(d)
    assert [s["job_id"] for s in submits] == ids       # arrival order
    assert cancels[0]["job_id"] == ids[1] and not drain and not rejected
    for e in submits + cancels:
        store.consume(e)
    assert store.scan_inbox(d) == ([], [], False, [])
    store.request_drain(d)
    assert store.scan_inbox(d)[2] is True


# ---------------------------------------------------------------------------
# spec -> tenant
# ---------------------------------------------------------------------------

def test_app_from_spec_serve_maps_to_llm_infer():
    app, dur = app_from_spec({"kind": "serve", "rps": 25.0, "duration": 3.0,
                              "priority": "hp", "quota_slices": 6,
                              "slo_latency": 0.2}, fallback_name="x")
    assert app.kind == "llm_infer" and app.rps == 25.0
    assert app.quota_slices == 6 and dur == 3.0


@pytest.mark.parametrize("spec", [
    {"kind": "nope"},
    {"kind": "train", "arch": "not-an-arch"},
    {"kind": "train", "duration": -1},
    {"kind": "serve", "rps": 0.0},
    {"kind": "train", "priority": "urgent"},
])
def test_app_from_spec_rejects(spec):
    with pytest.raises(JobSpecError):
        app_from_spec(spec, fallback_name="x")


# ---------------------------------------------------------------------------
# in-process daemon lifecycle
# ---------------------------------------------------------------------------

def _run_until(cp, pred, max_wall=60.0):
    t0 = time.time()
    while time.time() - t0 < max_wall:
        cp.tick()
        if pred():
            return
    raise AssertionError("daemon did not converge")


@pytest.mark.parametrize("engine", ["ref", "vec"])
def test_daemon_lifecycle(tmp_path, engine):
    d = str(tmp_path)
    hp = store.request_submit(d, {"kind": "serve", "rps": 30.0,
                                  "duration": 0.6, "priority": "hp",
                                  "quota_slices": 6})
    be = store.request_submit(d, {"kind": "train", "duration": 0.4})
    bad = store.request_submit(d, {"kind": "bogus"})
    cp = ControlPlane(d, DaemonConfig(n_devices=2, engine=engine,
                                      poll_interval=0.0))
    _run_until(cp, lambda: all(j.terminal for j in cp.jobs.values()))
    jobs = cp.jobs
    assert jobs[hp].state is JobState.DONE
    assert jobs[hp].granted_slices == 6
    assert jobs[hp].result["n_completed"] > 0
    assert jobs[be].state is JobState.DONE
    assert jobs[be].result["n_completed"] > 0
    assert jobs[bad].state is JobState.FAILED and "bogus" in jobs[bad].error
    # the two tenants were spread across the two devices
    assert jobs[hp].device != jobs[be].device
    # the journal is the truth: replay reproduces the live table
    cp.shutdown()
    rep = store.replay(d)
    for jid, j in jobs.items():
        assert rep[jid].state is j.state and rep[jid].result == j.result
    # data plane is clean: no clients, no owned slices, ledger empty
    for sim, pol in zip(cp.coord.sims, cp.coord.policies):
        assert not sim.client_by_id
        sm = getattr(pol, "slices", None)
        if sm is not None:
            assert all(o is None for o in sm.owner)
    assert not cp.coord.ledger.current


def test_daemon_cancel_running_job(tmp_path):
    d = str(tmp_path)
    jid = store.request_submit(d, {"kind": "train", "duration": 50.0})
    cp = ControlPlane(d, DaemonConfig(n_devices=1, poll_interval=0.0))
    _run_until(cp, lambda: cp.jobs[jid].state is JobState.RUNNING)
    store.request_cancel(d, jid)
    _run_until(cp, lambda: cp.jobs[jid].terminal)
    assert cp.jobs[jid].state is JobState.CANCELLED
    assert cp.jobs[jid].result["n_completed"] >= 0
    cp.shutdown()
    assert not cp.coord.sims[0].client_by_id      # detached, not leaked


def test_daemon_quota_admission_control(tmp_path):
    """One 54-slice device: two 40-slice tenants cannot coexist — the
    second waits in QUEUED until the first finishes, then runs."""
    d = str(tmp_path)
    a = store.request_submit(d, {"kind": "serve", "rps": 20.0,
                                 "duration": 0.4, "priority": "hp",
                                 "quota_slices": 40})
    b = store.request_submit(d, {"kind": "serve", "rps": 20.0,
                                 "duration": 0.4, "priority": "hp",
                                 "quota_slices": 40})
    cp = ControlPlane(d, DaemonConfig(n_devices=1, poll_interval=0.0))
    saw_b_waiting = False

    def done():
        nonlocal saw_b_waiting
        if (cp.jobs[a].state is JobState.RUNNING
                and cp.jobs[b].state is JobState.QUEUED):
            saw_b_waiting = True
        return all(j.terminal for j in cp.jobs.values())

    _run_until(cp, done)
    assert saw_b_waiting, "admission control never made b wait"
    assert cp.jobs[a].state is JobState.DONE
    assert cp.jobs[b].state is JobState.DONE
    cp.shutdown()


def test_daemon_drain_preempts_and_recovers(tmp_path):
    d = str(tmp_path)
    jid = store.request_submit(d, {"kind": "train", "duration": 30.0})
    cp = ControlPlane(d, DaemonConfig(n_devices=1, poll_interval=0.0))
    _run_until(cp, lambda: cp.jobs[jid].state is JobState.RUNNING)
    store.request_drain(d)
    cp.run(max_wall=30.0)           # drains: preempts the job, then exits
    assert cp.jobs[jid].state is JobState.PREEMPTED
    # next incarnation resumes it
    cp2 = ControlPlane(d, DaemonConfig(n_devices=1, poll_interval=0.0))
    assert cp2.jobs[jid].state is JobState.QUEUED
    assert cp2.jobs[jid].recoveries == 1
    cp2.shutdown()


# ---------------------------------------------------------------------------
# crash recovery (the acceptance criterion): kill -9, restart, no loss
# ---------------------------------------------------------------------------

def _ctl(args, **kw):
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    return subprocess.run([sys.executable, "-m", "repro.ctl", *args],
                          env=env, capture_output=True, text=True, **kw)


def _replay_states(d):
    return {jid: j.state for jid, j in store.replay(d).items()}


def test_kill9_recovery_subprocess(tmp_path):
    d = str(tmp_path)
    a = store.request_submit(d, {"kind": "serve", "rps": 25.0,
                                 "duration": 6.0, "priority": "hp",
                                 "quota_slices": 6, "name": "svc"})
    b = store.request_submit(d, {"kind": "train", "duration": 5.0,
                                 "name": "trn"})
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.ctl", "daemon", "--state-dir", d,
         "--devices", "2"], env=env,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        deadline = time.time() + 60
        while time.time() < deadline:
            st = _replay_states(d)
            if (st.get(a) is JobState.RUNNING
                    and st.get(b) is JobState.RUNNING):
                break
            time.sleep(0.2)
        else:
            raise AssertionError(f"jobs never both RUNNING: {_replay_states(d)}")
    finally:
        proc.kill()                  # SIGKILL: no shutdown hook runs
        proc.wait()
    hb = store.read_heartbeat(d)
    assert hb is not None and not hb["alive"]
    before = _replay_states(d)
    assert before == {a: JobState.RUNNING, b: JobState.RUNNING}

    # restart against the same state dir: recovery requeues and re-runs
    r = _ctl(["daemon", "--state-dir", d, "--devices", "2",
              "--exit-when-idle", "--max-wall", "120"], timeout=180)
    assert r.returncode == 0, r.stderr
    jobs = store.replay(d)
    assert set(jobs) == {a, b}, "no job lost, none duplicated"
    for jid in (a, b):
        assert jobs[jid].state is JobState.DONE, (jid, jobs[jid].public())
        assert jobs[jid].recoveries == 1
        assert jobs[jid].result["n_completed"] > 0


def test_status_verb_json(tmp_path):
    d = str(tmp_path)
    store.request_submit(d, {"kind": "train", "duration": 0.2,
                             "name": "tiny"})
    r = _ctl(["daemon", "--state-dir", d, "--exit-when-idle",
              "--max-wall", "90", "--devices", "1"], timeout=150)
    assert r.returncode == 0, r.stderr
    out = _ctl(["status", "--state-dir", d, "--json"], timeout=30)
    assert out.returncode == 0, out.stderr
    doc = json.loads(out.stdout)
    assert doc["jobs"][0]["state"] == "done"
    assert doc["daemon"]["alive"] is False
