"""Fault-tolerance integration: train -> fail hosts -> elastic re-mesh ->
restore from checkpoint -> resume, all on CPU with logical devices.

This is the end-to-end recovery path a 1000-node deployment exercises:
the coordinator detects the failure, elastic.py computes the largest valid
mesh from survivors, and the (mesh-independent) checkpoint restores onto
the new topology.  Run in a subprocess so the 8-device XLA flag doesn't
leak into the suite.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.checkpoint.sharded import CheckpointManager
    from repro.configs.registry import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.distributed.coordinator import Coordinator, CoordinatorConfig
    from repro.distributed.elastic import shrink_mesh, survivors
    from repro.launch import shardings as shlib
    from repro.models.sharding import use_mesh
    from repro.train.step import TrainConfig, make_train_step

    ckpt_dir = sys.argv[1]
    cfg = get_config("olmo-1b").reduced()
    tc = TrainConfig(total_steps=20, warmup_steps=2)
    init_state, train_step = make_train_step(cfg, tc)

    def run_steps(mesh, state, data, n):
        state_shapes = jax.eval_shape(init_state, jax.random.PRNGKey(0))
        st_sh = shlib.train_state_shardings(state_shapes, cfg, mesh)
        state = jax.device_put(state, st_sh)
        step = jax.jit(train_step, in_shardings=(st_sh, None),
                       out_shardings=(st_sh, None))
        with use_mesh(mesh):
            for _ in range(n):
                b = next(data)
                state, m = step(state, {k: jnp.asarray(v)
                                        for k, v in b.items()})
        return state, float(m["loss"])

    # phase 1: 4 data x 2 model mesh (8 "hosts" of 1 device each)
    devs = jax.devices()
    mesh1 = Mesh(np.array(devs).reshape(4, 2), ("data", "model"))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=0)).batches()
    state = init_state(jax.random.PRNGKey(0))
    state, loss1 = run_steps(mesh1, state, data, 4)
    mgr = CheckpointManager(ckpt_dir)
    mgr.save(state, step=4, async_write=False)

    # phase 2: hosts 6,7 fail -> coordinator detects -> shrink to 3x2
    clock = [0.0]
    coord = Coordinator(8, CoordinatorConfig(suspect_after=5, fail_after=10),
                        clock=lambda: clock[0])
    for t in range(0, 16, 2):
        clock[0] = float(t)
        for h in range(6):
            coord.heartbeat(h)
        coord.check()
    assert sorted(coord.alive()) == [0, 1, 2, 3, 4, 5], coord.alive()

    surv = survivors(devs, failed_hosts=[6, 7], devices_per_host=1)
    mesh2 = shrink_mesh(surv, model_parallel=2)
    assert mesh2.shape == {"data": 3, "model": 2}, mesh2.shape

    # phase 3: restore the 4x2 checkpoint onto the 3x2 mesh and resume
    template = jax.eval_shape(init_state, jax.random.PRNGKey(0))
    restored = mgr.restore(template)
    assert int(np.asarray(restored.opt.step)) == 4
    data2 = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                   global_batch=6, seed=1)).batches()
    restored, loss2 = run_steps(mesh2, restored, data2, 3)
    assert np.isfinite(loss2)
    print(f"RECOVERY_OK loss1={loss1:.4f} loss2={loss2:.4f}")
""")


@pytest.mark.slow
def test_failure_recovery_elastic_resume(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT, str(tmp_path)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert "RECOVERY_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
