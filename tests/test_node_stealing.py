"""Node-level lending protocol (cross-device TPC stealing).

Covers the ISSUE-2 contracts:
* ``migration=off`` ⇒ bit-for-bit identical to the historical sequential
  ``evaluate_node`` (independent per-device runs);
* conservation invariants hold across devices after migrations (NodeLedger
  mirrors the SliceMap lend ledger);
* a saturated-device + idle-device scenario where stealing strictly
  improves BE throughput without hurting the HP tenant;
* predictor warm-start on the target device;
* ``frac_throughput`` counts kernels-per-job from the sim's own records
  (satellite bugfix — solo train throughput unchanged vs the old resample).
"""
import os
import sys

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.lithos import evaluate, make_policy, run_alone
from repro.core.node import NodeCoordinator, evaluate_node
from repro.core.simulator import Simulator
from repro.core.types import DeviceSpec, NodeConfig, NodeSpec, Priority
from repro.core.workloads import AppSpec

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.scenarios import frac_throughput  # noqa: E402

DEV = DeviceSpec.a100_like()
OLMO = get_config("olmo-1b")
WHISPER = get_config("whisper-small")


def hp_app(rps=25.0, name="hp", cfg=OLMO):
    return AppSpec(name, cfg, "fwd_infer", priority=Priority.HIGH,
                   rps=rps, prompt_mix=((128, 1.0),), batch=4, fusion=8,
                   slo_latency=0.08)


def be_train(name="be", cfg=OLMO):
    return AppSpec(name, cfg, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=512, fusion=8)


def adversarial_mix():
    """Everything pinned on device 0, device 1 idle — the burst-at-one-
    service / stale-forecast shape the router can get wrong."""
    apps = [hp_app(name="hp0"), be_train(name="be0"), be_train(name="be1")]
    return apps, [0, 0, 0]


STEAL_CFG = NodeConfig(migration=True, epoch=0.1, migration_cost=0.02,
                       cooldown=5.0, free_hi=0.5, free_lo=0.2,
                       hp_depth_hi=3, validate=True)


# -- exact no-migration parity ------------------------------------------------

def _sequential_reference(system, node, apps, placement, horizon, seed):
    """The historical evaluate_node loop: each device's simulator runs to
    completion independently, in device order."""
    results, policies = [], []
    for d, dev in enumerate(node.devices):
        idx = [i for i, p in enumerate(placement) if p == d]
        dev_apps = [apps[i] for i in idx]
        policy = make_policy(system, dev, dev_apps, cids=idx)
        sim = Simulator(dev, dev_apps, policy, horizon=horizon, seed=seed,
                        cids=idx)
        results.append(sim.run())
        policies.append(policy)
    return results


@pytest.mark.parametrize("system", ["lithos", "mps", "reef"])
def test_migration_off_parity_with_sequential_runs(system):
    """Interleaved event streams with migration=off are bit-for-bit the
    independent sequential per-device runs (kernel ids aside — they come
    from a process-global counter and never influence scheduling)."""
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app(name="hpA"), hp_app(name="hpB", cfg=WHISPER, rps=10.0),
            be_train(name="beA"), be_train(name="beB")]
    placement = [0, 1, 0, 1]
    ref = _sequential_reference(system, node, apps, placement, 2.0, 3)
    res = evaluate_node(system, node, apps, horizon=2.0, seed=3,
                        placement=placement,
                        node_config=NodeConfig(migration=False))
    assert res.migrations == 0
    for d, (a, b) in enumerate(zip(ref, res.per_device)):
        assert a.energy == b.energy
        assert a.busy_slice_seconds == b.busy_slice_seconds
        assert len(a.records) == len(b.records)
        for ra, rb in zip(a.records, b.records):
            assert (ra.t_submit, ra.t_start, ra.t_end, ra.slices, ra.freq) \
                == (rb.t_submit, rb.t_start, rb.t_end, rb.slices, rb.freq)
        for ca, cb in zip(a.clients, b.clients):
            assert ca.cid == cb.cid and ca.name == cb.name
            assert ca.latencies == cb.latencies
            assert ca.slice_seconds == cb.slice_seconds
            assert ca.n_completed == cb.n_completed


def test_default_node_config_is_migration_off():
    node = NodeSpec.uniform(2, DEV)
    apps, placement = adversarial_mix()
    res = evaluate_node("lithos", node, apps, horizon=1.0, seed=0,
                        placement=placement)
    assert res.migrations == 0
    assert res.final_placement == placement


# -- the lending protocol end-to-end -----------------------------------------

@pytest.fixture(scope="module")
def steal_pair():
    node = NodeSpec.uniform(2, DEV)
    apps, placement = adversarial_mix()
    static = evaluate_node("lithos", node, apps, horizon=3.0, seed=7,
                           placement=placement,
                           node_config=NodeConfig(migration=False))
    steal = evaluate_node("lithos", node, apps, horizon=3.0, seed=7,
                          placement=placement, node_config=STEAL_CFG)
    return static, steal


def test_stealing_migrates_a_be_client(steal_pair):
    _, steal = steal_pair
    assert steal.migrations >= 1
    moved = [cid for cid, d in steal.ledger.current.items()
             if d != steal.ledger.home[cid]]
    assert moved, "at least one BE tenant should end up away from home"
    # only BE tenants move; the HP service stays put
    assert steal.final_placement[0] == 0
    assert all(cid in (1, 2) for cid in moved)


def test_conservation_across_devices_after_migration(steal_pair):
    _, steal = steal_pair
    coord = steal.coordinator
    assert coord.check()          # hosting map, ledger, per-device SliceMaps
    # ledger mirrors SliceMap's: open records exactly the off-home clients,
    # closed durations sum to the counter
    ledger = steal.ledger
    open_recs = [r for r in ledger.ledger if r.open]
    off_home = {cid for cid, d in ledger.current.items()
                if d != ledger.home[cid]}
    assert {r.cid for r in open_recs} == off_home
    assert ledger.donated_seconds(steal.horizon) > 0
    # every client is reported exactly once across per-device results
    cids = sorted(c.cid for r in steal.per_device for c in r.clients)
    assert cids == [0, 1, 2]


def test_stealing_improves_be_throughput_without_hurting_hp(steal_pair):
    static, steal = steal_pair
    h = static.horizon
    be_static = sum(frac_throughput(static, n, h) for n in ("be0", "be1"))
    be_steal = sum(frac_throughput(steal, n, h) for n in ("be0", "be1"))
    assert be_steal > 1.2 * be_static, (be_steal, be_static)
    # HP quota intact: the HP service loses nothing (BE contention left)
    hp_s, hp_m = static.client("hp0"), steal.client("hp0")
    assert hp_m.n_completed >= hp_s.n_completed
    slo = 0.08
    assert hp_m.slo_attainment(slo) >= hp_s.slo_attainment(slo) - 1e-9


def test_predictor_warm_started_on_target(steal_pair):
    _, steal = steal_pair
    (t0, cid, src, dst) = steal.coordinator.migration_log[0]
    # the source exported its observations; the target now owns them
    src_keys = [k for k in steal.policies[src].predictor.nodes if k[0] == cid]
    dst_keys = [k for k in steal.policies[dst].predictor.nodes if k[0] == cid]
    assert not src_keys
    assert dst_keys
    assert any(st.count > 0 for st in
               (steal.policies[dst].predictor.nodes[k] for k in dst_keys))


def test_migration_cost_delays_first_dispatch(steal_pair):
    _, steal = steal_pair
    (t0, cid, src, dst) = steal.coordinator.migration_log[0]
    dst_recs = [r for r in steal.per_device[dst].records
                if r.task.client_id == cid]
    assert dst_recs, "migrated client should run on the target"
    first = min(r.t_start for r in dst_recs)
    assert first >= t0 + STEAL_CFG.migration_cost - 1e-9


def test_open_loop_migrant_does_not_duplicate_arrivals():
    """Arrivals that fired on the source before the migration must not be
    re-seeded on the target: each completed job's arrival is unique and the
    completion count never exceeds the client's issued jobs."""
    node = NodeSpec.uniform(2, DEV)
    be_inf = AppSpec("be_inf", OLMO, "fwd_infer",
                     priority=Priority.BEST_EFFORT, rps=20.0,
                     prompt_mix=((128, 1.0),), batch=4, fusion=8)
    apps = [hp_app(name="hp0"), be_train(name="be0"), be_inf]
    cfg = NodeConfig(migration=True, epoch=0.1, migration_cost=0.02,
                     cooldown=5.0, free_hi=0.5, free_lo=0.2,
                     hp_depth_hi=3, validate=True)
    res = evaluate_node("lithos", node, apps, horizon=3.0, seed=7,
                        placement=[0, 0, 0], node_config=cfg)
    cm = res.client("be_inf")
    assert len(set(cm.arrivals)) == len(cm.arrivals), "duplicate arrivals"
    if res.migrations and 2 in (cid for _, cid, _, _ in
                                res.coordinator.migration_log):
        # the open-loop BE tenant moved: its stream must stay one stream
        assert len(cm.arrivals) == cm.n_completed


def test_be_client_with_explicit_quota_is_pinned():
    """A BEST_EFFORT tenant with an explicit quota owns slices, and slice
    ownership is static — the coordinator must not offer it for migration
    (previously crashed export_client_state's ownership assert)."""
    node = NodeSpec.uniform(2, DEV)
    quota_be = AppSpec("qbe", OLMO, "train", priority=Priority.BEST_EFFORT,
                       train_batch=2, train_seq=512, fusion=8,
                       quota_slices=8)
    apps = [hp_app(name="hp0"), quota_be, be_train(name="be1")]
    res = evaluate_node("lithos", node, apps, horizon=2.0, seed=7,
                        placement=[0, 0, 0], node_config=STEAL_CFG)
    # the quota-less trainer may move; the quota-owning one never does
    assert res.final_placement[1] == 0
    assert all(cid != 1 for _, cid, _, _ in res.coordinator.migration_log)


def test_max_migrations_cap():
    node = NodeSpec.uniform(2, DEV)
    apps, placement = adversarial_mix()
    cfg = NodeConfig(migration=True, epoch=0.1, migration_cost=0.02,
                     cooldown=0.0, free_hi=0.5, free_lo=0.2,
                     max_migrations=1, validate=True)
    res = evaluate_node("lithos", node, apps, horizon=2.0, seed=7,
                        placement=placement, node_config=cfg)
    assert res.migrations <= 1


def test_node_evaluate_facade_passes_node_config():
    node = NodeSpec.uniform(2, DEV)
    apps, placement = adversarial_mix()
    res = evaluate("lithos", node, apps, horizon=2.0, seed=7,
                   placement=placement, node_config=STEAL_CFG)
    assert res.migrations >= 1


def test_holds_are_counted_not_boolean():
    """A stale scheduled unhold (the migration-cost release of an earlier
    move) must not cancel a newer drain-hold on the same client — otherwise
    the protocol stalls whenever cooldown < migration_cost."""
    app = be_train()
    policy = make_policy("lithos", DEV, [app])
    Simulator(DEV, [app], policy, horizon=0.1, seed=0)
    policy.hold_client(0)               # migration-cost hold
    policy.hold_client(0)               # newer drain hold
    policy.release_hold(0)              # stale unhold fires
    assert 0 in policy._held, "drain hold must survive the stale release"
    policy.release_hold(0)
    assert 0 not in policy._held
    policy.release_hold(0)              # over-release: no-op
    assert 0 not in policy._held


def test_single_device_rejects_node_kwargs():
    """node_config/placement silently ignored on the DeviceSpec path would
    fake a stealing run — they must be rejected loudly."""
    with pytest.raises(ValueError):
        evaluate("lithos", DEV, [be_train()], horizon=0.1,
                 node_config=NodeConfig(migration=True))
    with pytest.raises(ValueError):
        evaluate("lithos", DEV, [be_train()], horizon=0.1, placement=[0])


# -- frac_throughput satellite bugfix ----------------------------------------

def test_frac_throughput_solo_train_unchanged():
    """For deterministic train traces the sim-derived kernels-per-job must
    equal the old (0, app.seed, 0)-resample estimate, so solo-run
    throughput is unchanged by the fix."""
    app = be_train(name="solo")
    res = run_alone(DEV, app, horizon=2.0, seed=0)
    rng = np.random.default_rng((0, app.seed, 0))
    old_per_job = max(1, len(app.job_trace(rng)))
    cm = res.client("solo")
    assert cm.kernels_per_job == old_per_job
    old = (sum(1 for r in res.records
               if r.task.client_id == cm.cid and r.task.atom_of is None)
           + sum(1.0 / r.task.atom_of[2] for r in res.records
                 if r.task.client_id == cm.cid and r.task.atom_of))
    assert frac_throughput(res, "solo", 2.0) == \
        pytest.approx(old / old_per_job / 2.0)


def test_frac_throughput_uses_sim_records_not_resample():
    """Stochastic LLM traces: kernels-per-job comes from the jobs the sim
    actually issued, not a fresh RNG stream."""
    app = AppSpec("llm", get_config("llama3-8b"), "llm_infer",
                  priority=Priority.HIGH, rps=4.0, fusion=8,
                  prompt_mix=((512, 1.0),), decode_tokens=8)
    res = evaluate("lithos", DEV, [app], horizon=2.0, seed=1)
    cm = res.client("llm")
    if cm.n_completed == 0:
        pytest.skip("no jobs completed in the short horizon")
    assert cm.kernels_per_job > 0
    # matches the mean of the client's own issued jobs by construction;
    # a resample with the old hardcoded stream generally does not
    thr = frac_throughput(res, "llm", 2.0)
    assert thr > 0
