"""Fault domains: injectable device/slice failures, HP evacuation, and
no-job-lost recovery across the hierarchy.

Covers the whole fault path end to end: the :class:`FaultPlan` schedule
itself, injection parity across both simulator engines (with the explicit
fault-free golden — an empty plan is bit-for-bit the no-plan run), slice
retirement under live holds, device death with HP elastic re-own on the
destination, KV-floor-aware evacuation placement, and the control plane's
journaled PREEMPT -> REQUEUE recovery (plus the spool-quarantine and
journal-compaction satellites that keep that journal trustworthy)."""
import json
import os
import time

import numpy as np
import pytest

try:                # only the property test needs hypothesis; plain tests run
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.configs.registry import get_config
from repro.core.lithos import evaluate
from repro.core.slices import SliceMap, VecSliceMap
from repro.core.types import (ClusterConfig, ClusterSpec, DeviceSpec,
                              FaultEvent, FaultPlan, NodeConfig, NodeSpec,
                              Priority, reset_kernel_ids)
from repro.core.workloads import AppSpec, fault_schedule, kv_floor_slices
from repro.ctl import store
from repro.ctl.daemon import ControlPlane, DaemonConfig
from repro.ctl.state import JobState

pytestmark = pytest.mark.fault

OLMO = get_config("olmo-1b")
LLAMA = get_config("llama3-8b")
DEV = DeviceSpec.a100_like()
ENGINES = ("ref", "vec")


def hp_app(name="hp", rps=20.0, seed=0):
    return AppSpec(name, OLMO, "fwd_infer", priority=Priority.HIGH, rps=rps,
                   prompt_mix=((128, 1.0),), batch=4, fusion=8, seed=seed)


def be_train(name="be", seed=0):
    return AppSpec(name, LLAMA, "train", priority=Priority.BEST_EFFORT,
                   train_batch=2, train_seq=2048, fusion=8, seed=seed)


def sig(res):
    return [(r.task.kid, r.task.queue_id, r.task.ordinal, r.t_submit,
             r.t_start, r.t_end, r.slices, r.freq) for r in res.records]


def run_node(engine, faults=None, horizon=6.0, ncfg=None):
    reset_kernel_ids()
    node = NodeSpec.uniform(2, DEV)
    apps = [hp_app("hp0"), hp_app("hp1", seed=1),
            be_train("be0"), be_train("be1", seed=1)]
    return evaluate("lithos", node, apps, horizon=horizon, seed=0,
                    placement=[0, 1, 0, 1], engine=engine, faults=faults,
                    node_config=ncfg or NodeConfig(migration=True,
                                                   validate=True))


# ---------------------------------------------------------------------------
# FaultPlan / fault_schedule
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(t=1.0, kind="meteor_strike")
    with pytest.raises(ValueError):
        FaultEvent(t=-1.0, kind="device_dead")
    with pytest.raises(ValueError):
        FaultEvent(t=1.0, kind="slice_retired")          # needs slice_id
    with pytest.raises(ValueError):
        FaultEvent(t=1.0, kind="transient_stall")        # needs duration


def test_fault_plan_routing():
    plan = FaultPlan(events=(
        FaultEvent(t=2.0, kind="device_dead", member=1),
        FaultEvent(t=1.0, kind="slice_retired", member=1, slice_id=3),
        FaultEvent(t=0.5, kind="transient_stall", member=0, duration=1e-3)))
    assert plan.dead_members == (1,)
    assert [f.t for f in plan.events_for(1)] == [1.0, 2.0]   # sorted by t
    assert plan.events_for(2) == ()


def test_fault_schedule_deterministic():
    kw = dict(n_device_dead=1, n_slice_retired=2, n_transient=2,
              slices_per_device=DEV.n_slices)
    a = fault_schedule(4, 10.0, seed=7, **kw)
    b = fault_schedule(4, 10.0, seed=7, **kw)
    c = fault_schedule(4, 10.0, seed=8, **kw)
    assert a == b
    assert a != c
    assert len(a.events) == 5
    # non-fatal faults only land on survivors
    for f in a.events:
        if f.kind != "device_dead":
            assert f.member not in a.dead_members
        assert 0.2 * 10.0 <= f.t <= 0.8 * 10.0


# ---------------------------------------------------------------------------
# injection: golden + parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_no_fault_golden(engine):
    """An empty FaultPlan is bit-for-bit the no-plan run: fault support
    must cost fault-free runs nothing, on both engines."""
    base = run_node(engine, faults=None)
    empty = run_node(engine, faults=FaultPlan(events=()))
    assert sig(base) == sig(empty)


def test_engine_parity_with_faults():
    plan = FaultPlan(events=(
        FaultEvent(t=2.0, kind="device_dead", member=0),
        FaultEvent(t=1.0, kind="slice_retired", member=1, slice_id=5),
        FaultEvent(t=1.5, kind="transient_stall", member=1, duration=10e-3)))
    a = run_node("ref", faults=plan)
    b = run_node("vec", faults=plan)
    assert sig(a) == sig(b)
    assert a.coordinator.failed_members == b.coordinator.failed_members
    assert dict(a.coordinator.ledger.current) == dict(
        b.coordinator.ledger.current)


@pytest.mark.parametrize("engine", ENGINES)
def test_transient_stall_delays_only_the_future(engine):
    t_stall = 1.0
    plan = FaultPlan(events=(
        FaultEvent(t=t_stall, kind="transient_stall", member=0,
                   duration=50e-3),))
    base = run_node(engine, faults=None, horizon=3.0)
    hit = run_node(engine, faults=plan, horizon=3.0)
    before = lambda s: [r for r in s if r[5] <= t_stall]     # r[5] = t_end
    assert before(sig(base)) == before(sig(hit))
    assert sig(base) != sig(hit)                             # stall is felt
    # the stall pushes in-flight completions out, never pulls them in
    b_end = {r[0]: r[5] for r in sig(base)}
    h_end = {r[0]: r[5] for r in sig(hit)}
    common = set(b_end) & set(h_end)
    assert all(h_end[k] >= b_end[k] - 1e-12 for k in common)
    assert any(h_end[k] > b_end[k] for k in common)


# ---------------------------------------------------------------------------
# slice retirement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_slice_retired_shrinks_live_capacity(engine):
    reset_kernel_ids()
    plan = FaultPlan(events=(
        FaultEvent(t=1.0, kind="slice_retired", member=0, slice_id=0),
        FaultEvent(t=1.5, kind="slice_retired", member=0, slice_id=7)))
    res = evaluate("lithos", DEV, [hp_app(), be_train()], horizon=4.0,
                   seed=0, engine=engine, faults=plan)
    sm = res.policy.slices
    assert sm.retired == {0, 7}
    assert sm.counts()["retired"] == 2
    sm.check()
    # quotas are guarantees: they must stay coverable by live capacity
    total_quota = sum(q.slices for q in res.policy.quotas.values())
    assert total_quota <= DEV.n_slices - 2
    assert len(res.records) > 0


@pytest.mark.parametrize("cls", (SliceMap, VecSliceMap))
def test_retire_held_slice_waits_for_release(cls):
    sm = cls(8)
    sm.assign_owner(0, cid=1)
    sm.acquire([0, 1], kid=42, borrower=1, now=0.0, eta=1.0)
    assert sm.retire(1) is False                 # held: pending
    assert sm.retire(2) is True                  # idle pool: immediate
    assert 2 in sm.retired and 1 not in sm.retired
    sm.release(42, 1.0)
    assert 1 in sm.retired                       # retired at release
    assert sm.counts()["retired"] == 2
    sm.check()
    assert set(sm.idle_pool()).isdisjoint({1, 2})


# ---------------------------------------------------------------------------
# device death: evacuation across the hierarchy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_device_dead_evacuates_all_tenants(engine):
    t_dead = 2.0
    plan = FaultPlan(events=(
        FaultEvent(t=t_dead, kind="device_dead", member=0),))
    res = run_node(engine, faults=plan)
    coord = res.coordinator
    assert coord.failed_members == {0}
    assert coord.fault_log and coord.fault_log[0][1] == 0
    assert not coord.stranded
    # everyone ends up on the survivor, and keeps completing there
    assert all(d == 1 for d in coord.ledger.current.values())
    moved = {cid for _, cid, src, dst in coord.migration_log if src == 0}
    assert moved == {0, 2}                       # hp0 + be0 lived on dev 0
    for cid in moved:
        assert any(r.task.queue_id == cid and r.t_end > t_dead
                   for r in res.records), f"cid {cid} starved after fault"
    # HP elastic re-own: the destination re-derives fair HP shares — the
    # incumbent's monopoly quota (54) splits into 27/27
    quotas = coord.policies[1].quotas
    assert quotas[0].slices == quotas[1].slices == DEV.n_slices // 2
    assert not coord.policies[1]._pending_reown


def test_device_dead_with_no_destination_strands():
    reset_kernel_ids()
    plan = FaultPlan(events=(
        FaultEvent(t=1.0, kind="device_dead", member=0),))
    res = evaluate("lithos", NodeSpec.uniform(1, DEV),
                   [hp_app(), be_train()], horizon=3.0, seed=0,
                   placement=[0, 0], faults=plan,
                   node_config=NodeConfig(migration=True))
    coord = res.coordinator
    assert coord.failed_members == {0}
    assert coord.stranded == {0, 1}              # nowhere to go: flagged


def test_evacuation_respects_kv_floor():
    """A destination whose live capacity cannot cover a tenant's KV floor
    is not a fit; ``can_host`` is the gate the evacuator uses."""
    reset_kernel_ids()
    from repro.core.node import build_node
    coord = build_node("lithos", NodeSpec.uniform(2, DEV),
                       [hp_app(), hp_app("hp1", seed=1)], [0, 1],
                       horizon=1.0, seed=0, engine="ref",
                       node_config=NodeConfig(migration=True))
    m = coord.members[1]

    class _C:                                   # client-shaped probe
        def __init__(self, kv_bytes):
            self.spec = hp_app()
            self.kv_bytes = kv_bytes

    assert m.can_host(_C(0.0))
    floor_all = kv_floor_slices(OLMO, DEV, 1e18)     # clamps to n_slices
    assert floor_all == DEV.n_slices
    assert m.can_host(_C(1e18))                      # fits exactly, no faults
    m.sim.n_retired = 1                              # one slice gone
    assert not m.can_host(_C(1e18))                  # floor no longer fits
    m.sim.n_retired = 0
    m.sim.dead = True
    assert not m.can_host(_C(0.0))                   # dead hosts nothing


# ---------------------------------------------------------------------------
# control plane: device loss is journaled, jobs recover, none lost
# ---------------------------------------------------------------------------

def _run_daemon(tmp_path, cfg, max_wall=60.0):
    cp = ControlPlane(str(tmp_path), cfg)
    cp.run(max_wall=max_wall, exit_when_idle=True)
    return (store.replay(str(tmp_path)),
            store._read_records(os.path.join(str(tmp_path), store.JOURNAL)))


def test_ctl_device_loss_requeues_and_recovers(tmp_path):
    plan = FaultPlan(events=(
        FaultEvent(t=0.5, kind="device_dead", member=0),))
    jids = [store.request_submit(
        str(tmp_path), {"kind": "serve", "rps": 40.0, "duration": 2.0,
                        "priority": "hp", "quota_slices": 8,
                        "name": f"svc{i}"}) for i in range(3)]
    jobs, recs = _run_daemon(
        tmp_path, DaemonConfig(n_devices=2, fault_plan=plan, validate=True,
                               poll_interval=0.0))
    assert set(jobs) == set(jids)
    for jid in jids:                             # never silently lost
        assert jobs[jid].state is JobState.DONE, (jid, jobs[jid].error)
        assert sum(1 for r in recs
                   if r["job"] == jid and r["event"] == "finish") == 1
    faults = [r for r in recs if r["event"] == "fault"]
    assert len(faults) == 1 and faults[0]["device"] == 0
    hit = [jid for jid in jids if jobs[jid].recoveries >= 1]
    assert set(faults[0]["jobs"]) == set(hit) and hit
    for jid in hit:                              # PREEMPT carries the fault
        pre = [r for r in recs if r["job"] == jid and r["event"] == "preempt"]
        assert any(r.get("fault", {}).get("device") == 0 for r in pre)
        assert jobs[jid].device == 1             # finished on the survivor


def test_ctl_quarantines_corrupt_spool_files(tmp_path):
    d = str(tmp_path)
    good = store.request_submit(d, {"kind": "serve", "rps": 20.0,
                                    "duration": 0.2, "priority": "be"})
    inbox = os.path.join(d, "inbox")
    with open(os.path.join(inbox,
                           f"{time.time_ns():020d}-trunc.submit.json"),
              "w") as f:
        f.write('{"job_id": "trunc", "spe')               # truncated JSON
    with open(os.path.join(inbox,
                           f"{time.time_ns():020d}-noise.submit.json"),
              "wb") as f:
        f.write(b"\x00\xff\xfe not json at all")          # binary garbage
    jobs, recs = _run_daemon(
        tmp_path, DaemonConfig(n_devices=1, poll_interval=0.0))
    assert jobs[good].state is JobState.DONE
    # corrupt files are quarantined, not retried forever
    rejected = sorted(os.listdir(os.path.join(inbox, "rejected")))
    assert len(rejected) == 2
    assert not any(n.endswith(".submit.json")
                   for n in os.listdir(inbox))            # inbox is clean
    # identifiable jobs get a journaled FAIL instead of vanishing
    for jid in ("trunc", "noise"):
        assert jobs[jid].state is JobState.FAILED
        assert "rejected spool file" in jobs[jid].error


def test_compact_preserves_replay(tmp_path):
    d = str(tmp_path)
    j = store.Journal(d)
    for i in range(20):
        jid = f"job-{i:03d}"
        j.append(jid, store.SUBMIT, spec={"kind": "train", "i": i},
                 to="queued")
        j.append(jid, "admit", cid=i, device=i % 2)
        j.append(jid, "start", granted=4, admitted_sim=float(i),
                 ends_sim=float(i) + 1.0)
        if i % 3 == 0:
            j.append(jid, "preempt")
            j.append(jid, "requeue")
            j.append(jid, "admit", cid=100 + i, device=(i + 1) % 2)
            j.append(jid, "start", granted=4, admitted_sim=float(i) + 2.0,
                     ends_sim=float(i) + 3.0)
        if i < 15:                               # 15 terminal, 5 live
            j.append(jid, "finish", result={"n_completed": i})
    j.append("device-1", "fault", device=1, sim_now=9.0, jobs=[])
    j.close()
    before = store.replay(d)
    n_before = len(store._read_records(os.path.join(d, store.JOURNAL)))
    dropped = store.compact(d)
    recs = store._read_records(os.path.join(d, store.JOURNAL))
    assert dropped > 0 and len(recs) == n_before - dropped
    assert [r["seq"] for r in recs] == list(range(len(recs)))
    after = store.replay(d)
    assert set(before) == set(after)
    for jid in before:
        for attr in ("state", "cid", "device", "granted_slices",
                     "admitted_sim", "ends_sim", "recoveries", "migrations",
                     "error", "result", "submitted_wall", "updated_wall"):
            assert getattr(before[jid], attr) == getattr(after[jid], attr), \
                (jid, attr)
    # terminal jobs collapse to one snapshot; live jobs keep full history
    per_job = {}
    for r in recs:
        per_job[r["job"]] = per_job.get(r["job"], 0) + 1
    for i in range(15):
        assert per_job[f"job-{i:03d}"] == 1
    for i in range(15, 20):
        assert per_job[f"job-{i:03d}"] >= 3
    assert any(r["event"] == "fault" for r in recs)   # fault record survives
    assert store.compact(d) == 0                      # idempotent
    # a journal reopened after compaction appends at the renumbered tail
    j2 = store.Journal(d)
    assert j2.seq == len(recs)
    j2.close()


def test_daemon_compacts_over_threshold(tmp_path):
    d = str(tmp_path)
    for i in range(6):
        store.request_submit(d, {"kind": "serve", "rps": 20.0,
                                 "duration": 0.2, "priority": "be",
                                 "name": f"tiny{i}"})
    jobs, recs = _run_daemon(
        tmp_path, DaemonConfig(n_devices=1, poll_interval=0.0,
                               compact_threshold_bytes=1))
    assert all(j.state is JobState.DONE for j in jobs.values())
    # every terminal job's history is a single snapshot record
    per_job = {}
    for r in recs:
        per_job[r["job"]] = per_job.get(r["job"], 0) + 1
    assert all(n == 1 for n in per_job.values()), per_job
    assert all(r.get("compacted") for r in recs)


# ---------------------------------------------------------------------------
# property: random fault plans never break conservation or lose a tenant
# ---------------------------------------------------------------------------

def _check_cluster_under_plan(seed, n_dead, n_ret, n_tr):
    reset_kernel_ids()
    cluster = ClusterSpec.uniform(2, NodeSpec.uniform(2, DEV))
    apps = [hp_app("hp0"), hp_app("hp1", seed=1),
            be_train("be0"), be_train("be1", seed=1)]
    placement = [(0, 0), (1, 0), (0, 1), (1, 1)]
    plan = fault_schedule(4, 3.0, seed=seed, n_device_dead=n_dead,
                          n_slice_retired=n_ret, n_transient=n_tr,
                          slices_per_device=DEV.n_slices)
    res = evaluate("lithos", cluster, apps, horizon=3.0, seed=0,
                   placement=placement, faults=plan,
                   cluster_config=ClusterConfig(
                       migration=True, validate=True,
                       node_config=NodeConfig(migration=True,
                                              validate=True)))
    top = res.coordinator
    # a tenant is never left owned by a dead member unless it is flagged
    # stranded (nowhere alive to go)
    for cid, n in top.ledger.current.items():
        assert n not in top.failed_members or cid in top.stranded, \
            (cid, n, top.failed_members, top.stranded)
    for nm in top.members:
        inner = nm.coord
        for cid, d in inner.ledger.current.items():
            assert (d not in inner.failed_members
                    or cid in inner.stranded), (cid, d)
        # slice conservation on every surviving device
        for d, p in enumerate(inner.policies):
            sm = getattr(p, "slices", None)
            if sm is not None and d not in inner.failed_members:
                sm.check()


if HAS_HYPOTHESIS:
    @given(seed=st.integers(0, 1_000_000), n_dead=st.integers(0, 2),
           n_ret=st.integers(0, 3), n_tr=st.integers(0, 3))
    @settings(max_examples=10, deadline=None)
    def test_random_fault_plans_preserve_invariants(seed, n_dead, n_ret,
                                                    n_tr):
        _check_cluster_under_plan(seed, n_dead, n_ret, n_tr)
else:
    def test_random_fault_plans_preserve_invariants():
        pytest.skip("hypothesis not installed")


@pytest.mark.parametrize("seed,n_dead,n_ret,n_tr",
                         [(0, 1, 2, 1), (1, 2, 1, 0), (2, 0, 3, 3)])
def test_fixed_fault_plans_preserve_invariants(seed, n_dead, n_ret, n_tr):
    """Deterministic slice of the property test so the invariants run in
    environments without hypothesis."""
    _check_cluster_under_plan(seed, n_dead, n_ret, n_tr)
